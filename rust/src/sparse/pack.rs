//! Packed weight matrices for the serving path: one pruned linear layer in
//! the storage/compute format the sparse engine will execute it in —
//! CSR for unstructured sparsity, bitmask-packed n:m for the structured
//! regime, plain dense for layers the pruner left (nearly) dense, or their
//! quantized twins (`qcsr` / `qnm` / `qdense`: u8-coded values at 2..=8
//! bits behind the same index/bitmask streams — see
//! [`crate::sparse::quant`]).
//!
//! f32 packing is *lossless over the value grid the kernels see*:
//! `to_dense` of a packed matrix equals the pruned dense matrix
//! elementwise, and the packed `layer` kernels visit surviving weights in
//! the same order as `dense_layer`, so packed decode is element-identical
//! to dense decode (pinned by the proptests). Quantized packing rounds
//! surviving values onto a [`QuantGrid`] once at pack time; decode is then
//! element-identical to quantize-then-dense-decode (pinned by
//! `tests/quant_parity.rs`).

use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use crate::solver::quant::QuantGrid;
use crate::sparse::buf::SectionBuf;
use crate::sparse::gemm::dense_layer_slice;
use crate::sparse::quant::{code_stream_len, QCsrMatrix, QDenseMatrix, QNmMatrix};
use crate::sparse::{CsrMatrix, NmMatrix};
use crate::tensor::Tensor;
use crate::util::mmap::{ByteSource, MmapRegion};

/// Which storage format to pack a matrix into.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PackFormat {
    /// per-matrix choice: n:m when the pattern holds, CSR when sparse
    /// enough, dense otherwise. Never picks a quantized format —
    /// quantization is lossy and always an explicit request.
    Auto,
    Dense,
    Csr,
    /// CSR with rows stored in nonzero-descending order (permutation kept
    /// in the matrix; bit-identical results — see `CsrMatrix::perm`)
    CsrPerm,
    Nm(usize, usize),
    /// quantized dense fallback: survivor bitmask + `bits`-bit codes;
    /// `group` = columns per (scale, zero) pair, 0 = per-row
    QDense { bits: u8, group: usize },
    /// quantized CSR: index stream + `bits`-bit codes
    QCsr { bits: u8, group: usize },
    /// quantized n:m: group bitmasks + `bits`-bit codes; the n:m pattern
    /// is detected per matrix (2:4 preferred, then 4:8)
    QNm { bits: u8, group: usize },
}

impl PackFormat {
    pub fn parse(s: &str) -> Result<PackFormat> {
        let err = || {
            anyhow!(
                "unknown pack format {s:?} (expected auto|dense|csr|csr:perm|n:m \
                 or q{{dense,csr,nm}}:<bits>[,g=<cols>], e.g. qcsr:4,g=128)"
            )
        };
        // quantized labels: q<fmt>:<bits>[,g=<cols>]
        let (base, group) = match s.split_once(",g=") {
            Some((b, g)) => {
                let g: usize = g.parse().map_err(|_| err())?;
                (b, Some(g))
            }
            None => (s, None),
        };
        if let Some((name, bits)) = base.split_once(':') {
            if matches!(name, "qdense" | "qcsr" | "qnm") {
                let bits: u8 = bits.parse().map_err(|_| err())?;
                if !(2..=8).contains(&bits) {
                    bail!("quantized pack format {s:?} needs 2..=8 bits per code");
                }
                let group = group.unwrap_or(0);
                return Ok(match name {
                    "qdense" => PackFormat::QDense { bits, group },
                    "qcsr" => PackFormat::QCsr { bits, group },
                    _ => PackFormat::QNm { bits, group },
                });
            }
        }
        if group.is_some() {
            // g= modifies quantized grids only
            return Err(err());
        }
        match base {
            "auto" => Ok(PackFormat::Auto),
            "dense" => Ok(PackFormat::Dense),
            "csr" => Ok(PackFormat::Csr),
            "csr:perm" => Ok(PackFormat::CsrPerm),
            other => {
                let (n, m) = other.split_once(':').ok_or_else(err)?;
                let (n, m): (usize, usize) =
                    (n.parse().map_err(|_| err())?, m.parse().map_err(|_| err())?);
                if n == 0 || m <= n || m > 8 {
                    bail!("invalid n:m pack format {other:?} (need 0 < n < m <= 8)");
                }
                Ok(PackFormat::Nm(n, m))
            }
        }
    }

    pub fn label(&self) -> String {
        fn q(name: &str, bits: u8, group: usize) -> String {
            if group == 0 {
                format!("{name}:{bits}")
            } else {
                format!("{name}:{bits},g={group}")
            }
        }
        match self {
            PackFormat::Auto => "auto".to_string(),
            PackFormat::Dense => "dense".to_string(),
            PackFormat::Csr => "csr".to_string(),
            PackFormat::CsrPerm => "csr:perm".to_string(),
            PackFormat::Nm(n, m) => format!("{n}:{m}"),
            PackFormat::QDense { bits, group } => q("qdense", *bits, *group),
            PackFormat::QCsr { bits, group } => q("qcsr", *bits, *group),
            PackFormat::QNm { bits, group } => q("qnm", *bits, *group),
        }
    }

    /// Replace the quantization group size; errors on f32 formats (the
    /// serve label's standalone `g=<cols>` knob).
    pub fn with_group(self, g: usize) -> Result<PackFormat> {
        Ok(match self {
            PackFormat::QDense { bits, .. } => PackFormat::QDense { bits, group: g },
            PackFormat::QCsr { bits, .. } => PackFormat::QCsr { bits, group: g },
            PackFormat::QNm { bits, .. } => PackFormat::QNm { bits, group: g },
            other => bail!("g={g} only applies to quantized pack formats (got {})", other.label()),
        })
    }

    /// The quantization group size (0 for f32 formats / per-row grids).
    pub fn group(&self) -> usize {
        match self {
            PackFormat::QDense { group, .. }
            | PackFormat::QCsr { group, .. }
            | PackFormat::QNm { group, .. } => *group,
            _ => 0,
        }
    }
}

/// How the packer chooses formats under [`PackFormat::Auto`].
#[derive(Clone, Copy, Debug)]
pub struct PackPolicy {
    pub format: PackFormat,
    /// `Auto` only: matrices denser than this stay dense (the "fall back
    /// to `dense_layer` for unpruned layers" rule).
    pub dense_cutoff: f64,
}

impl Default for PackPolicy {
    fn default() -> PackPolicy {
        PackPolicy { format: PackFormat::Auto, dense_cutoff: 0.95 }
    }
}

impl PackPolicy {
    pub fn with_format(format: PackFormat) -> PackPolicy {
        PackPolicy { format, ..Default::default() }
    }
}

/// A dense weight matrix whose payload may be a view straight into a
/// mapped `.spkt` section ([`SectionBuf`]) rather than a `Tensor`-owned
/// `Vec<f32>` — the zero-copy carrier for layers the pruner left dense.
#[derive(Clone, Debug)]
pub struct DenseMatrix {
    pub rows: usize,
    pub cols: usize,
    /// row-major (rows, cols) f32 payload
    pub data: SectionBuf<f32>,
}

impl DenseMatrix {
    pub fn from_tensor(t: &Tensor) -> DenseMatrix {
        DenseMatrix { rows: t.rows(), cols: t.cols(), data: t.data().to_vec().into() }
    }

    pub fn to_tensor(&self) -> Tensor {
        Tensor::new(vec![self.rows, self.cols], self.data.to_vec())
    }

    /// y = x @ W^T through [`dense_layer_slice`] — element-identical to
    /// `dense_layer` on the equivalent `Tensor`.
    pub fn layer(&self, x: &Tensor) -> Tensor {
        dense_layer_slice(x, &self.data, self.rows, self.cols)
    }
}

/// One weight matrix in its serving format.
#[derive(Clone, Debug)]
pub enum PackedMatrix {
    Dense(DenseMatrix),
    Csr(CsrMatrix),
    Nm(NmMatrix),
    QDense(QDenseMatrix),
    QCsr(QCsrMatrix),
    QNm(QNmMatrix),
}

/// Does `w` satisfy the n:m constraint (at most n nonzeros per group)?
fn satisfies_nm(w: &Tensor, n: usize, m: usize) -> bool {
    if w.cols() % m != 0 {
        return false;
    }
    for r in 0..w.rows() {
        let row = w.row(r);
        for g in (0..w.cols()).step_by(m) {
            if row[g..g + m].iter().filter(|&&v| v != 0.0).count() > n {
                return false;
            }
        }
    }
    true
}

impl PackedMatrix {
    /// Pack a (pruned) dense matrix per `policy`.
    pub fn pack(w: &Tensor, policy: &PackPolicy) -> Result<PackedMatrix> {
        match policy.format {
            PackFormat::Dense => Ok(PackedMatrix::Dense(DenseMatrix::from_tensor(w))),
            PackFormat::Csr => Ok(PackedMatrix::Csr(CsrMatrix::from_dense(w)?)),
            PackFormat::CsrPerm => Ok(PackedMatrix::Csr(CsrMatrix::from_dense_permuted(w)?)),
            PackFormat::Nm(n, m) => Ok(PackedMatrix::Nm(NmMatrix::from_dense(w, n, m)?)),
            PackFormat::QDense { bits, group } => {
                Ok(PackedMatrix::QDense(QDenseMatrix::from_dense(w, bits, group)?))
            }
            PackFormat::QCsr { bits, group } => {
                Ok(PackedMatrix::QCsr(QCsrMatrix::from_dense(w, bits, group)?))
            }
            PackFormat::QNm { bits, group } => {
                // the n:m pattern is per-matrix: prefer 2:4, else 4:8
                for (n, m) in [(2usize, 4usize), (4, 8)] {
                    if satisfies_nm(w, n, m) {
                        return Ok(PackedMatrix::QNm(QNmMatrix::from_dense(
                            w, n, m, bits, group,
                        )?));
                    }
                }
                bail!("matrix satisfies neither 2:4 nor 4:8 — qnm needs an n:m-pruned matrix");
            }
            PackFormat::Auto => {
                let density = 1.0 - w.sparsity();
                if density > policy.dense_cutoff {
                    return Ok(PackedMatrix::Dense(DenseMatrix::from_tensor(w)));
                }
                for (n, m) in [(2usize, 4usize), (4, 8)] {
                    // prefer the structured format only when the pattern is
                    // genuinely n:m (not merely implied by deep sparsity)
                    if density > (n as f64 / m as f64) * 0.5 && satisfies_nm(w, n, m) {
                        return Ok(PackedMatrix::Nm(NmMatrix::from_dense(w, n, m)?));
                    }
                }
                Ok(PackedMatrix::Csr(CsrMatrix::from_dense(w)?))
            }
        }
    }

    pub fn rows(&self) -> usize {
        match self {
            PackedMatrix::Dense(d) => d.rows,
            PackedMatrix::Csr(c) => c.rows,
            PackedMatrix::Nm(n) => n.rows,
            PackedMatrix::QDense(q) => q.rows,
            PackedMatrix::QCsr(q) => q.rows,
            PackedMatrix::QNm(q) => q.rows,
        }
    }

    pub fn cols(&self) -> usize {
        match self {
            PackedMatrix::Dense(d) => d.cols,
            PackedMatrix::Csr(c) => c.cols,
            PackedMatrix::Nm(n) => n.cols,
            PackedMatrix::QDense(q) => q.cols,
            PackedMatrix::QCsr(q) => q.cols,
            PackedMatrix::QNm(q) => q.cols,
        }
    }

    /// Surviving weights: nonzero-representable for the f32 formats,
    /// structurally stored (code-bearing) for the quantized ones.
    pub fn nnz(&self) -> usize {
        match self {
            PackedMatrix::Dense(d) => d.data.iter().filter(|&&v| v != 0.0).count(),
            PackedMatrix::Csr(c) => c.nnz(),
            PackedMatrix::Nm(n) => n.values.iter().filter(|&&v| v != 0.0).count(),
            PackedMatrix::QDense(q) => q.nnz(),
            PackedMatrix::QCsr(q) => q.nnz(),
            PackedMatrix::QNm(q) => q.nnz(),
        }
    }

    pub fn density(&self) -> f64 {
        self.nnz() as f64 / (self.rows() * self.cols()).max(1) as f64
    }

    pub fn format_label(&self) -> &'static str {
        match self {
            PackedMatrix::Dense(_) => "dense",
            PackedMatrix::Csr(c) if c.perm.is_some() => "csr:perm",
            PackedMatrix::Csr(_) => "csr",
            PackedMatrix::Nm(_) => "nm",
            PackedMatrix::QDense(_) => "qdense",
            PackedMatrix::QCsr(_) => "qcsr",
            PackedMatrix::QNm(_) => "qnm",
        }
    }

    /// (code bits, TOC group-size) for quantized matrices — the group is 0
    /// when the grid is per-row. `None` for the f32 formats.
    pub fn quant_meta(&self) -> Option<(u8, u16)> {
        let (bits, grid, cols) = match self {
            PackedMatrix::QDense(q) => (q.bits, &q.grid, q.cols),
            PackedMatrix::QCsr(q) => (q.bits, &q.grid, q.cols),
            PackedMatrix::QNm(q) => (q.bits, &q.grid, q.cols),
            _ => return None,
        };
        let group = if grid.group_cols >= cols { 0 } else { grid.group_cols as u16 };
        Some((bits, group))
    }

    /// Storage bits per weight under the paper's Fig.-6 accounting:
    /// value bits on survivors plus a 1-bit mask (f32 formats count 32
    /// value bits; plain dense has no mask). Scale/zero metadata is
    /// excluded — it amortizes as O(1/group) bits.
    pub fn effective_bits(&self) -> f64 {
        let value_bits = match self {
            PackedMatrix::Dense(_) => return 32.0,
            PackedMatrix::Csr(_) | PackedMatrix::Nm(_) => 32.0,
            PackedMatrix::QDense(q) => q.bits as f64,
            PackedMatrix::QCsr(q) => q.bits as f64,
            PackedMatrix::QNm(q) => q.bits as f64,
        };
        self.density() * value_bits + 1.0
    }

    /// y = x @ W^T through the matching kernel. All kernels share the
    /// token-major tile skeleton and visit surviving weights in the same
    /// order, so switching formats never perturbs f32 results (the
    /// quantized kernels additionally dequantize in-loop with the exact
    /// [`QuantGrid::decode`] operations).
    pub fn layer(&self, x: &Tensor) -> Tensor {
        match self {
            PackedMatrix::Dense(d) => d.layer(x),
            PackedMatrix::Csr(c) => c.layer(x),
            PackedMatrix::Nm(n) => n.layer(x),
            PackedMatrix::QDense(q) => q.layer(x),
            PackedMatrix::QCsr(q) => q.layer(x),
            PackedMatrix::QNm(q) => q.layer(x),
        }
    }

    /// Bytes of this matrix's streams currently served from mapped pages.
    pub fn mapped_bytes(&self) -> u64 {
        match self {
            PackedMatrix::Dense(d) => d.data.mapped_bytes(),
            PackedMatrix::Csr(c) => {
                c.row_ptr.mapped_bytes()
                    + c.col_idx.mapped_bytes()
                    + c.values.mapped_bytes()
                    + c.perm.as_ref().map_or(0, |p| p.mapped_bytes())
            }
            PackedMatrix::Nm(n) => n.values.mapped_bytes() + n.offsets.mapped_bytes(),
            PackedMatrix::QDense(q) => q.mask.mapped_bytes() + q.codes.mapped_bytes(),
            PackedMatrix::QCsr(q) => {
                q.row_ptr.mapped_bytes() + q.col_idx.mapped_bytes() + q.codes.mapped_bytes()
            }
            PackedMatrix::QNm(q) => q.masks.mapped_bytes() + q.codes.mapped_bytes(),
        }
    }

    /// Total stream payload bytes, however backed (mapped or owned).
    pub fn payload_bytes(&self) -> u64 {
        match self {
            PackedMatrix::Dense(d) => d.data.payload_bytes(),
            PackedMatrix::Csr(c) => {
                c.row_ptr.payload_bytes()
                    + c.col_idx.payload_bytes()
                    + c.values.payload_bytes()
                    + c.perm.as_ref().map_or(0, |p| p.payload_bytes())
            }
            PackedMatrix::Nm(n) => n.values.payload_bytes() + n.offsets.payload_bytes(),
            PackedMatrix::QDense(q) => q.mask.payload_bytes() + q.codes.payload_bytes(),
            PackedMatrix::QCsr(q) => {
                q.row_ptr.payload_bytes() + q.col_idx.payload_bytes() + q.codes.payload_bytes()
            }
            PackedMatrix::QNm(q) => q.masks.payload_bytes() + q.codes.payload_bytes(),
        }
    }

    pub fn to_dense(&self) -> Tensor {
        match self {
            PackedMatrix::Dense(d) => d.to_tensor(),
            PackedMatrix::Csr(c) => c.to_dense(),
            PackedMatrix::Nm(n) => n.to_dense(),
            PackedMatrix::QDense(q) => q.to_dense(),
            PackedMatrix::QCsr(q) => q.to_dense(),
            PackedMatrix::QNm(q) => q.to_dense(),
        }
    }

    // ---- byte serialization (little-endian; the sparse_store sections) ----

    const TAG_DENSE: u8 = 0;
    const TAG_CSR: u8 = 1;
    const TAG_NM: u8 = 2;
    const TAG_QDENSE: u8 = 3;
    const TAG_QCSR: u8 = 4;
    const TAG_QNM: u8 = 5;
    const TAG_CSRP: u8 = 6;

    /// Append this matrix's byte encoding to `out`.
    ///
    /// ```text
    /// dense:  tag=0 u8, pad[3], rows u32, cols u32, f32 * rows*cols
    /// csr:    tag=1 u8, pad[3], rows u32, cols u32, nnz u64,
    ///         row_ptr u32 * (rows+1), col_idx u32 * nnz, values f32 * nnz
    /// nm:     tag=2 u8, n u8, m u8, pad[1], rows u32, cols u32, kept u64,
    ///         group bitmasks u8 * (rows*cols/m)  (bit j = column g*m+j kept),
    ///         pad to 4, values f32 * kept        (set bits, ascending)
    /// grid:   levels u32, group_cols u32, cols u32, pairs u32,
    ///         (scale f32, zero f32) * pairs      (row-major groups)
    /// qdense: tag=3 u8, bits u8, pad[2], rows u32, cols u32, kept u64,
    ///         grid, survivor bitmask u8 * ceil(rows*cols/8),
    ///         codes u8 * ceil(kept*bits/8)
    /// qcsr:   tag=4 u8, bits u8, pad[2], rows u32, cols u32, nnz u64,
    ///         grid, row_ptr u32 * (rows+1), col_idx u32 * nnz,
    ///         codes u8 * ceil(nnz*bits/8)
    /// qnm:    tag=5 u8, n u8, m u8, bits u8, rows u32, cols u32, kept u64,
    ///         grid, group bitmasks u8 * (rows*cols/m),
    ///         codes u8 * ceil(kept*bits/8)
    /// csrp:   tag=6 u8, pad[3], rows u32, cols u32, nnz u64,
    ///         perm u32 * rows (perm[i] = logical row stored at slot i),
    ///         row_ptr u32 * (rows+1), col_idx u32 * nnz, values f32 * nnz
    /// ```
    pub fn write_bytes(&self, out: &mut Vec<u8>) {
        match self {
            PackedMatrix::Dense(d) => {
                out.push(Self::TAG_DENSE);
                out.extend_from_slice(&[0u8; 3]);
                out.extend_from_slice(&(d.rows as u32).to_le_bytes());
                out.extend_from_slice(&(d.cols as u32).to_le_bytes());
                for v in &d.data {
                    out.extend_from_slice(&v.to_le_bytes());
                }
            }
            PackedMatrix::Csr(c) => {
                match &c.perm {
                    None => {
                        out.push(Self::TAG_CSR);
                        out.extend_from_slice(&[0u8; 3]);
                        out.extend_from_slice(&(c.rows as u32).to_le_bytes());
                        out.extend_from_slice(&(c.cols as u32).to_le_bytes());
                        out.extend_from_slice(&(c.nnz() as u64).to_le_bytes());
                    }
                    Some(perm) => {
                        out.push(Self::TAG_CSRP);
                        out.extend_from_slice(&[0u8; 3]);
                        out.extend_from_slice(&(c.rows as u32).to_le_bytes());
                        out.extend_from_slice(&(c.cols as u32).to_le_bytes());
                        out.extend_from_slice(&(c.nnz() as u64).to_le_bytes());
                        for v in perm {
                            out.extend_from_slice(&v.to_le_bytes());
                        }
                    }
                }
                for v in &c.row_ptr {
                    out.extend_from_slice(&v.to_le_bytes());
                }
                for v in &c.col_idx {
                    out.extend_from_slice(&v.to_le_bytes());
                }
                for v in &c.values {
                    out.extend_from_slice(&v.to_le_bytes());
                }
            }
            PackedMatrix::Nm(nm) => {
                debug_assert!(nm.m <= 8, "n:m bitmask packing needs m <= 8");
                out.push(Self::TAG_NM);
                out.push(nm.n as u8);
                out.push(nm.m as u8);
                out.push(0u8);
                out.extend_from_slice(&(nm.rows as u32).to_le_bytes());
                out.extend_from_slice(&(nm.cols as u32).to_le_bytes());
                let groups = nm.rows * nm.cols / nm.m;
                // group bitmasks + surviving values in ascending-bit order
                let mut masks = vec![0u8; groups];
                let mut kept = Vec::new();
                for g in 0..groups {
                    // slots are stored in ascending within-group offset
                    // order by `NmMatrix::from_dense`, zero-padded at the end
                    for i in 0..nm.n {
                        let k = g * nm.n + i;
                        if nm.values[k] != 0.0 {
                            masks[g] |= 1u8 << nm.offsets[k];
                            kept.push(nm.values[k]);
                        }
                    }
                }
                out.extend_from_slice(&(kept.len() as u64).to_le_bytes());
                out.extend_from_slice(&masks);
                while out.len() % 4 != 0 {
                    out.push(0u8);
                }
                for v in &kept {
                    out.extend_from_slice(&v.to_le_bytes());
                }
            }
            PackedMatrix::QDense(q) => {
                out.push(Self::TAG_QDENSE);
                out.push(q.bits);
                out.extend_from_slice(&[0u8; 2]);
                out.extend_from_slice(&(q.rows as u32).to_le_bytes());
                out.extend_from_slice(&(q.cols as u32).to_le_bytes());
                out.extend_from_slice(&(q.kept as u64).to_le_bytes());
                write_grid(&q.grid, out);
                out.extend_from_slice(&q.mask);
                out.extend_from_slice(&q.codes);
            }
            PackedMatrix::QCsr(q) => {
                out.push(Self::TAG_QCSR);
                out.push(q.bits);
                out.extend_from_slice(&[0u8; 2]);
                out.extend_from_slice(&(q.rows as u32).to_le_bytes());
                out.extend_from_slice(&(q.cols as u32).to_le_bytes());
                out.extend_from_slice(&(q.nnz() as u64).to_le_bytes());
                write_grid(&q.grid, out);
                for v in &q.row_ptr {
                    out.extend_from_slice(&v.to_le_bytes());
                }
                for v in &q.col_idx {
                    out.extend_from_slice(&v.to_le_bytes());
                }
                out.extend_from_slice(&q.codes);
            }
            PackedMatrix::QNm(q) => {
                out.push(Self::TAG_QNM);
                out.push(q.n as u8);
                out.push(q.m as u8);
                out.push(q.bits);
                out.extend_from_slice(&(q.rows as u32).to_le_bytes());
                out.extend_from_slice(&(q.cols as u32).to_le_bytes());
                out.extend_from_slice(&(q.kept as u64).to_le_bytes());
                write_grid(&q.grid, out);
                out.extend_from_slice(&q.masks);
                out.extend_from_slice(&q.codes);
            }
        }
    }

    /// Decode one matrix from an owned byte buffer; returns it plus the
    /// bytes consumed. All streams come back owned (copied).
    pub fn read_bytes(buf: &[u8]) -> Result<(PackedMatrix, usize)> {
        Self::read_with(Reader { buf, i: 0, src: None })
    }

    /// Decode one matrix from `len` bytes at `off` inside a mapped region.
    /// Headers are validated exactly as in [`read_bytes`]; the bulk streams
    /// (indices, values, masks, codes) come back as zero-copy views into
    /// the region wherever alignment and endianness allow.
    pub fn read_bytes_mapped(
        region: &Arc<MmapRegion>,
        off: usize,
        len: usize,
    ) -> Result<(PackedMatrix, usize)> {
        let end = off.checked_add(len).filter(|&e| e <= region.len());
        let Some(end) = end else {
            bail!("packed-matrix section [{off}, +{len}) exceeds the mapped region");
        };
        let buf = &region.bytes()[off..end];
        Self::read_with(Reader { buf, i: 0, src: Some((region.clone(), off)) })
    }

    fn read_with(mut r: Reader) -> Result<(PackedMatrix, usize)> {
        let tag = r.u8()?;
        match tag {
            Self::TAG_DENSE => {
                r.skip(3)?;
                let rows = r.u32()? as usize;
                let cols = r.u32()? as usize;
                let n = rows
                    .checked_mul(cols)
                    .ok_or_else(|| anyhow!("dense extent {rows}x{cols} overflows"))?;
                let data = r.f32s(n)?;
                Ok((PackedMatrix::Dense(DenseMatrix { rows, cols, data }), r.i))
            }
            Self::TAG_CSR | Self::TAG_CSRP => {
                r.skip(3)?;
                let rows = r.u32()? as usize;
                let cols = r.u32()? as usize;
                let nnz = r.u64()? as usize;
                if nnz > rows * cols {
                    bail!("csr nnz {nnz} exceeds {rows}x{cols}");
                }
                if nnz > u32::MAX as usize {
                    // row_ptr is u32: a larger count cannot be represented
                    // (the writer refuses the same way — CsrMatrix::build)
                    bail!("csr nnz {nnz} exceeds the u32 index space");
                }
                let perm = if tag == Self::TAG_CSRP {
                    let p = r.u32s(rows)?;
                    let mut seen = vec![false; rows];
                    for &v in &p {
                        if v as usize >= rows || seen[v as usize] {
                            bail!("csr:perm row permutation is not a permutation of 0..{rows}");
                        }
                        seen[v as usize] = true;
                    }
                    Some(p)
                } else {
                    None
                };
                let row_ptr = r.u32s(rows + 1)?;
                if row_ptr.last().copied().unwrap_or(0) as usize != nnz {
                    bail!("csr row_ptr does not end at nnz");
                }
                if row_ptr.first().copied().unwrap_or(0) != 0
                    || row_ptr.windows(2).any(|w| w[0] > w[1])
                {
                    // non-monotonic pointers would make the kernels slice
                    // values[lo..hi] with lo > hi and panic mid-decode
                    bail!("csr row_ptr is not monotonically non-decreasing from 0");
                }
                let col_idx = r.u32s(nnz)?;
                if col_idx.iter().any(|&c| c as usize >= cols) {
                    bail!("csr column index out of range");
                }
                let values = r.f32s(nnz)?;
                let c = CsrMatrix { rows, cols, row_ptr, col_idx, values, perm };
                Ok((PackedMatrix::Csr(c), r.i))
            }
            Self::TAG_NM => {
                let n = r.u8()? as usize;
                let m = r.u8()? as usize;
                r.skip(1)?;
                let rows = r.u32()? as usize;
                let cols = r.u32()? as usize;
                if n == 0 || m <= n || m > 8 || cols % m != 0 {
                    bail!("nm header invalid: {n}:{m} over {rows}x{cols}");
                }
                let kept_n = r.u64()? as usize;
                let groups = rows * cols / m;
                let masks = r.bytes(groups)?.to_vec();
                r.align4()?;
                let kept = r.f32s(kept_n)?;
                // rebuild the zero-padded (values, offsets) slot arrays
                let mut values = Vec::with_capacity(groups * n);
                let mut offsets = Vec::with_capacity(groups * n);
                let mut ki = 0usize;
                for &mask in &masks {
                    let mut cnt = 0usize;
                    for j in 0..m {
                        if mask & (1u8 << j) != 0 {
                            if cnt == n || ki >= kept.len() {
                                bail!("nm group overflows {n}:{m} on decode");
                            }
                            values.push(kept[ki]);
                            offsets.push(j as u8);
                            ki += 1;
                            cnt += 1;
                        }
                    }
                    while cnt < n {
                        values.push(0.0);
                        offsets.push(0);
                        cnt += 1;
                    }
                }
                if ki != kept.len() {
                    bail!("nm kept-value count mismatch");
                }
                Ok((
                    PackedMatrix::Nm(NmMatrix {
                        n,
                        m,
                        rows,
                        cols,
                        values: values.into(),
                        offsets: offsets.into(),
                    }),
                    r.i,
                ))
            }
            Self::TAG_QDENSE => {
                let bits = r.u8()?;
                r.skip(2)?;
                let rows = r.u32()? as usize;
                let cols = r.u32()? as usize;
                let kept = r.u64()? as usize;
                if !(2..=8).contains(&bits) || kept > rows * cols {
                    bail!("qdense header invalid: {bits} bits, {kept} kept in {rows}x{cols}");
                }
                let grid = read_grid(&mut r, rows, cols, bits)?;
                let mask = r.u8s((rows * cols).div_ceil(8))?;
                let stored = mask
                    .iter()
                    .enumerate()
                    .map(|(byte, &b)| {
                        // count only bits inside the rows*cols range
                        let valid = (rows * cols).saturating_sub(byte * 8).min(8);
                        (b & mask_low_bits(valid)).count_ones() as usize
                    })
                    .sum::<usize>();
                if stored != kept {
                    bail!("qdense bitmask has {stored} survivors, header says {kept}");
                }
                let codes = r.u8s(code_stream_len(kept, bits))?;
                let q = QDenseMatrix { rows, cols, bits, mask, codes, kept, grid };
                Ok((PackedMatrix::QDense(q), r.i))
            }
            Self::TAG_QCSR => {
                let bits = r.u8()?;
                r.skip(2)?;
                let rows = r.u32()? as usize;
                let cols = r.u32()? as usize;
                let nnz = r.u64()? as usize;
                if !(2..=8).contains(&bits) || nnz > rows * cols {
                    bail!("qcsr header invalid: {bits} bits, {nnz} nnz in {rows}x{cols}");
                }
                if nnz > u32::MAX as usize {
                    bail!("qcsr nnz {nnz} exceeds the u32 index space");
                }
                let grid = read_grid(&mut r, rows, cols, bits)?;
                let row_ptr = r.u32s(rows + 1)?;
                if row_ptr.last().copied().unwrap_or(0) as usize != nnz
                    || row_ptr.first().copied().unwrap_or(0) != 0
                    || row_ptr.windows(2).any(|w| w[0] > w[1])
                {
                    bail!("qcsr row_ptr is not monotonically non-decreasing from 0 to nnz");
                }
                let col_idx = r.u32s(nnz)?;
                if col_idx.iter().any(|&c| c as usize >= cols) {
                    bail!("qcsr column index out of range");
                }
                let codes = r.u8s(code_stream_len(nnz, bits))?;
                let q = QCsrMatrix { rows, cols, bits, row_ptr, col_idx, codes, grid };
                Ok((PackedMatrix::QCsr(q), r.i))
            }
            Self::TAG_QNM => {
                let n = r.u8()? as usize;
                let m = r.u8()? as usize;
                let bits = r.u8()?;
                let rows = r.u32()? as usize;
                let cols = r.u32()? as usize;
                if n == 0 || m <= n || m > 8 || cols % m != 0 || !(2..=8).contains(&bits) {
                    bail!("qnm header invalid: {n}:{m} at {bits} bits over {rows}x{cols}");
                }
                let kept = r.u64()? as usize;
                let grid = read_grid(&mut r, rows, cols, bits)?;
                let groups = rows * cols / m;
                let masks = r.u8s(groups)?;
                let mut stored = 0usize;
                for &mask in &masks {
                    let c = (mask & mask_low_bits(m)).count_ones() as usize;
                    if mask & !mask_low_bits(m) != 0 || c > n {
                        bail!("qnm group mask violates {n}:{m} on decode");
                    }
                    stored += c;
                }
                if stored != kept {
                    bail!("qnm masks store {stored} entries, header says {kept}");
                }
                let codes = r.u8s(code_stream_len(kept, bits))?;
                let q = QNmMatrix { n, m, rows, cols, bits, masks, codes, kept, grid };
                Ok((PackedMatrix::QNm(q), r.i))
            }
            other => bail!("unknown packed-matrix tag {other}"),
        }
    }
}

/// A byte with the low `n` bits set (n <= 8).
fn mask_low_bits(n: usize) -> u8 {
    if n >= 8 {
        0xFF
    } else {
        (1u8 << n) - 1
    }
}

fn write_grid(grid: &QuantGrid, out: &mut Vec<u8>) {
    out.extend_from_slice(&grid.levels.to_le_bytes());
    out.extend_from_slice(&(grid.group_cols as u32).to_le_bytes());
    out.extend_from_slice(&(grid.cols as u32).to_le_bytes());
    out.extend_from_slice(&(grid.rows.len() as u32).to_le_bytes());
    for (s, z) in &grid.rows {
        out.extend_from_slice(&s.to_le_bytes());
        out.extend_from_slice(&z.to_le_bytes());
    }
}

fn read_grid(r: &mut Reader, rows: usize, cols: usize, bits: u8) -> Result<QuantGrid> {
    let levels = r.u32()?;
    let group_cols = r.u32()? as usize;
    let gcols = r.u32()? as usize;
    let pairs = r.u32()? as usize;
    if levels != (1u32 << bits) - 1 {
        bail!("grid levels {levels} do not match {bits}-bit codes");
    }
    if gcols != cols || group_cols == 0 || group_cols > cols {
        bail!("grid group {group_cols} invalid for {cols} columns (grid says {gcols})");
    }
    if pairs != rows * cols.div_ceil(group_cols) {
        bail!("grid has {pairs} (scale, zero) pairs, expected rows*groups");
    }
    let mut grows = Vec::with_capacity(pairs);
    for _ in 0..pairs {
        let s = r.f32()?;
        let z = r.f32()?;
        grows.push((s, z));
    }
    Ok(QuantGrid { levels, group_cols, cols, rows: grows })
}

struct Reader<'a> {
    buf: &'a [u8],
    i: usize,
    /// When decoding in place from a mapped region: the region plus the
    /// byte offset of `buf[0]` within it. Stream reads (`u8s`/`u32s`/
    /// `f32s`) then return views instead of copies.
    src: Option<(Arc<MmapRegion>, usize)>,
}

impl<'a> Reader<'a> {
    fn bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        // checked: `n` can come from a hostile u64 TOC field, so `i + n`
        // must not wrap around usize
        let end = self.i.checked_add(n).filter(|&e| e <= self.buf.len());
        let Some(end) = end else {
            bail!("packed matrix truncated at byte {}", self.i);
        };
        let out = &self.buf[self.i..end];
        self.i = end;
        Ok(out)
    }

    /// Section view of `n * size` bytes when mapped, aligned, and
    /// little-endian; an owned copy otherwise. `decode` turns the raw
    /// bytes into one element for the owned path.
    fn stream<T, F>(&mut self, n: usize, size: usize, decode: F) -> Result<SectionBuf<T>>
    where
        T: crate::sparse::buf::SectionElem,
        F: Fn(&[u8]) -> T,
    {
        let nbytes = n
            .checked_mul(size)
            .ok_or_else(|| anyhow!("packed stream of {n} elements overflows"))?;
        let start = self.i;
        let b = self.bytes(nbytes)?;
        if let Some((region, base)) = &self.src {
            let off = base + start;
            if cfg!(target_endian = "little") && off % std::mem::align_of::<T>() == 0 {
                // bounds were just proven by `bytes()`: buf ⊆ region
                return SectionBuf::mapped(region.clone(), off, n);
            }
        }
        Ok(b.chunks_exact(size).map(|c| decode(c)).collect::<Vec<T>>().into())
    }

    fn skip(&mut self, n: usize) -> Result<()> {
        self.bytes(n).map(|_| ())
    }

    fn align4(&mut self) -> Result<()> {
        while self.i % 4 != 0 {
            self.skip(1)?;
        }
        Ok(())
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.bytes(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.bytes(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.bytes(8)?.try_into().unwrap()))
    }

    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.bytes(4)?.try_into().unwrap()))
    }

    fn u8s(&mut self, n: usize) -> Result<SectionBuf<u8>> {
        self.stream(n, 1, |c| c[0])
    }

    fn u32s(&mut self, n: usize) -> Result<SectionBuf<u32>> {
        self.stream(n, 4, |c| u32::from_le_bytes(c.try_into().unwrap()))
    }

    fn f32s(&mut self, n: usize) -> Result<SectionBuf<f32>> {
        self.stream(n, 4, |c| f32::from_le_bytes(c.try_into().unwrap()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::magnitude::{magnitude_prune, magnitude_prune_nm};
    use crate::sparse::gemm::dense_layer;
    use crate::util::prng::Rng;

    fn random(seed: u64, r: usize, c: usize) -> Tensor {
        let mut rng = Rng::new(seed);
        Tensor::new(vec![r, c], (0..r * c).map(|_| rng.normal_f32()).collect())
    }

    /// Make row 0's first 8 columns dense so no n:m pattern (m <= 8) holds
    /// — keeps "unstructured but n:m-by-luck" out of deterministic tests.
    fn break_nm(mut w: Tensor) -> Tensor {
        for j in 0..8.min(w.cols()) {
            w.set2(0, j, 1.0 + j as f32);
        }
        w
    }

    #[test]
    fn auto_picks_by_structure() {
        let policy = PackPolicy::default();
        let dense = random(0, 8, 16);
        assert_eq!(PackedMatrix::pack(&dense, &policy).unwrap().format_label(), "dense");
        let w50 = break_nm(magnitude_prune(&random(1, 8, 16), 0.5).0);
        assert_eq!(PackedMatrix::pack(&w50, &policy).unwrap().format_label(), "csr");
        let (w24, _) = magnitude_prune_nm(&random(2, 8, 16), 2, 4);
        assert_eq!(PackedMatrix::pack(&w24, &policy).unwrap().format_label(), "nm");
    }

    #[test]
    fn forced_formats_respected() {
        let w = break_nm(magnitude_prune(&random(3, 6, 12), 0.5).0);
        for (fmt, label) in [
            (PackFormat::Dense, "dense"),
            (PackFormat::Csr, "csr"),
            (PackFormat::Auto, "csr"),
        ] {
            let p = PackedMatrix::pack(&w, &PackPolicy::with_format(fmt)).unwrap();
            assert_eq!(p.format_label(), label);
            assert_eq!(p.to_dense(), w);
        }
        // forcing n:m on a non-conforming matrix is a clean error
        let nm24 = PackPolicy::with_format(PackFormat::Nm(2, 4));
        assert!(PackedMatrix::pack(&random(3, 6, 12), &nm24).is_err());
    }

    #[test]
    fn bytes_roundtrip_all_formats() {
        let (w50, _) = magnitude_prune(&random(4, 9, 24), 0.6);
        let (w24, _) = magnitude_prune_nm(&random(5, 8, 24), 2, 4);
        let pol = PackPolicy::with_format;
        let cases = [
            PackedMatrix::pack(&random(6, 5, 7), &pol(PackFormat::Dense)).unwrap(),
            PackedMatrix::pack(&w50, &pol(PackFormat::Csr)).unwrap(),
            PackedMatrix::pack(&w24, &pol(PackFormat::Nm(2, 4))).unwrap(),
        ];
        for p in cases {
            let mut buf = Vec::new();
            p.write_bytes(&mut buf);
            let (q, used) = PackedMatrix::read_bytes(&buf).unwrap();
            assert_eq!(used, buf.len());
            assert_eq!(q.format_label(), p.format_label());
            assert_eq!(q.to_dense(), p.to_dense());
            assert_eq!(q.nnz(), p.nnz());
        }
    }

    #[test]
    fn layer_dispatch_matches_dense_kernel() {
        let (w, _) = magnitude_prune(&random(7, 16, 32), 0.5);
        let x = random(8, 5, 32);
        let want = dense_layer(&x, &w);
        for fmt in [PackFormat::Dense, PackFormat::Csr, PackFormat::CsrPerm] {
            let p = PackedMatrix::pack(&w, &PackPolicy::with_format(fmt)).unwrap();
            assert_eq!(p.layer(&x).data(), want.data(), "{}", p.format_label());
        }
        let (w24, _) = magnitude_prune_nm(&random(9, 16, 32), 2, 4);
        let want = dense_layer(&x, &w24);
        let p = PackedMatrix::pack(&w24, &PackPolicy::with_format(PackFormat::Nm(2, 4))).unwrap();
        assert_eq!(p.layer(&x).data(), want.data());
    }

    #[test]
    fn truncated_bytes_rejected() {
        let (w, _) = magnitude_prune(&random(10, 4, 8), 0.5);
        let p = PackedMatrix::pack(&w, &PackPolicy::with_format(PackFormat::Csr)).unwrap();
        let mut buf = Vec::new();
        p.write_bytes(&mut buf);
        for cut in [0, 1, 5, buf.len() - 1] {
            assert!(PackedMatrix::read_bytes(&buf[..cut]).is_err(), "cut {cut}");
        }
        assert!(PackedMatrix::read_bytes(&[9, 0, 0, 0]).is_err()); // bad tag
    }

    #[test]
    fn csr_non_monotonic_row_ptr_rejected() {
        // passes the nnz/col-range checks but would slice values[3..2] in
        // the kernels — must be a clean decode error, not a later panic
        let bad = CsrMatrix {
            rows: 2,
            cols: 4,
            row_ptr: vec![0, 3, 2].into(),
            col_idx: vec![0, 1].into(),
            values: vec![1.0, 2.0].into(),
            perm: None,
        };
        let mut buf = Vec::new();
        PackedMatrix::Csr(bad).write_bytes(&mut buf);
        assert!(PackedMatrix::read_bytes(&buf).is_err());
    }

    #[test]
    fn csr_perm_round_trips_and_bad_perms_rejected() {
        let (w, _) = magnitude_prune(&random(20, 7, 16), 0.55);
        let p = PackedMatrix::pack(&w, &PackPolicy::with_format(PackFormat::CsrPerm)).unwrap();
        assert_eq!(p.format_label(), "csr:perm");
        assert_eq!(p.to_dense(), w);
        let mut buf = Vec::new();
        p.write_bytes(&mut buf);
        let (q, used) = PackedMatrix::read_bytes(&buf).unwrap();
        assert_eq!(used, buf.len());
        assert_eq!(q.format_label(), "csr:perm");
        assert_eq!(q.to_dense(), p.to_dense());
        match (&p, &q) {
            (PackedMatrix::Csr(a), PackedMatrix::Csr(b)) => assert_eq!(a.perm, b.perm),
            _ => panic!("expected csr"),
        }
        // a perm that is not a permutation (duplicate slot) must not decode
        let mut evil = match q {
            PackedMatrix::Csr(c) => c,
            _ => unreachable!(),
        };
        let perm = evil.perm.as_mut().unwrap();
        perm[1] = perm[0];
        let mut buf = Vec::new();
        PackedMatrix::Csr(evil).write_bytes(&mut buf);
        assert!(PackedMatrix::read_bytes(&buf).is_err());
        // truncations stay clean decode errors
        let p2 = PackedMatrix::pack(&w, &PackPolicy::with_format(PackFormat::CsrPerm)).unwrap();
        let mut buf = Vec::new();
        p2.write_bytes(&mut buf);
        for cut in [0, 1, 9, buf.len() - 1] {
            assert!(PackedMatrix::read_bytes(&buf[..cut]).is_err(), "cut {cut}");
        }
    }

    #[test]
    fn format_parse_label_round_trip() {
        for s in [
            "auto",
            "dense",
            "csr",
            "csr:perm",
            "2:4",
            "4:8",
            "qdense:4",
            "qcsr:3",
            "qcsr:4,g=128",
            "qnm:8",
            "qnm:4,g=64",
        ] {
            assert_eq!(PackFormat::parse(s).unwrap().label(), s);
        }
        for bad in [
            "",
            "nm",
            "4:2",
            "0:4",
            "2:16",
            "qcsr",
            "qcsr:",
            "qcsr:1",
            "qcsr:9",
            "qcsr:x",
            "qcsr:4,g=",
            "qcsr:4,g=x",
            "dense,g=4",
            "2:4,g=8",
            "csr:perm,g=8",
            "csr:x",
        ] {
            assert!(PackFormat::parse(bad).is_err(), "{bad:?}");
        }
        // g=0 is the per-row default, so it canonicalizes away
        assert_eq!(PackFormat::parse("qcsr:4,g=0").unwrap().label(), "qcsr:4");
    }

    #[test]
    fn with_group_only_touches_quantized_formats() {
        let q = PackFormat::parse("qcsr:4").unwrap().with_group(32).unwrap();
        assert_eq!(q.label(), "qcsr:4,g=32");
        assert_eq!(q.group(), 32);
        assert!(PackFormat::Csr.with_group(32).is_err());
        assert_eq!(PackFormat::Csr.group(), 0);
    }

    #[test]
    fn quantized_bytes_roundtrip_all_formats() {
        let (w50, _) = magnitude_prune(&random(11, 9, 24), 0.6);
        let (w24, _) = magnitude_prune_nm(&random(12, 8, 24), 2, 4);
        let pol = PackPolicy::with_format;
        let cases = [
            PackedMatrix::pack(&random(13, 5, 8), &pol(PackFormat::QDense { bits: 4, group: 0 }))
                .unwrap(),
            PackedMatrix::pack(&w50, &pol(PackFormat::QCsr { bits: 3, group: 8 })).unwrap(),
            PackedMatrix::pack(&w50, &pol(PackFormat::QCsr { bits: 8, group: 0 })).unwrap(),
            PackedMatrix::pack(&w24, &pol(PackFormat::QNm { bits: 4, group: 12 })).unwrap(),
        ];
        for p in cases {
            let mut buf = Vec::new();
            p.write_bytes(&mut buf);
            let (q, used) = PackedMatrix::read_bytes(&buf).unwrap();
            assert_eq!(used, buf.len(), "{}", p.format_label());
            assert_eq!(q.format_label(), p.format_label());
            assert_eq!(q.to_dense().data(), p.to_dense().data(), "{}", p.format_label());
            assert_eq!(q.nnz(), p.nnz());
            assert_eq!(q.quant_meta(), p.quant_meta());
            assert_eq!(q.effective_bits(), p.effective_bits());
            // truncations stay clean decode errors
            for cut in [0, 1, 9, buf.len() - 1] {
                assert!(PackedMatrix::read_bytes(&buf[..cut]).is_err(), "cut {cut}");
            }
        }
    }

    #[test]
    fn mapped_decode_is_element_identical_to_owned_decode() {
        // the Reader-level mmap contract: a matrix decoded from a region
        // (views) equals the same bytes decoded owned (copies), for every
        // format, at an 8-aligned section offset like sparse_store uses
        let (w50, _) = magnitude_prune(&random(30, 9, 24), 0.6);
        let (w24, _) = magnitude_prune_nm(&random(31, 8, 24), 2, 4);
        let pol = PackPolicy::with_format;
        let cases = [
            PackedMatrix::pack(&random(32, 5, 7), &pol(PackFormat::Dense)).unwrap(),
            PackedMatrix::pack(&w50, &pol(PackFormat::Csr)).unwrap(),
            PackedMatrix::pack(&w50, &pol(PackFormat::CsrPerm)).unwrap(),
            PackedMatrix::pack(&w24, &pol(PackFormat::Nm(2, 4))).unwrap(),
            PackedMatrix::pack(&w50, &pol(PackFormat::QCsr { bits: 4, group: 8 })).unwrap(),
            PackedMatrix::pack(&w24, &pol(PackFormat::QNm { bits: 4, group: 0 })).unwrap(),
            PackedMatrix::pack(&random(33, 5, 8), &pol(PackFormat::QDense { bits: 4, group: 0 }))
                .unwrap(),
        ];
        let x = random(34, 3, 24);
        for p in cases {
            let mut buf = vec![0u8; 16]; // 8-aligned, nonzero section offset
            p.write_bytes(&mut buf);
            let region = Arc::new(MmapRegion::from_bytes(&buf));
            let (owned, n1) = PackedMatrix::read_bytes(&buf[16..]).unwrap();
            let (mapped, n2) =
                PackedMatrix::read_bytes_mapped(&region, 16, buf.len() - 16).unwrap();
            assert_eq!(n1, n2, "{}", p.format_label());
            assert_eq!(mapped.format_label(), owned.format_label());
            assert_eq!(mapped.to_dense().data(), owned.to_dense().data());
            if p.cols() == 24 {
                assert_eq!(
                    mapped.layer(&x).data(),
                    owned.layer(&x).data(),
                    "{}",
                    p.format_label()
                );
            }
            assert_eq!(mapped.payload_bytes(), owned.payload_bytes());
            assert_eq!(owned.mapped_bytes(), 0, "owned decode must not report mapped bytes");
        }
    }

    #[test]
    fn qnm_pack_detects_the_pattern_and_rejects_unstructured() {
        let fmt = PackFormat::QNm { bits: 4, group: 0 };
        let (w24, _) = magnitude_prune_nm(&random(14, 8, 24), 2, 4);
        let p = PackedMatrix::pack(&w24, &PackPolicy::with_format(fmt)).unwrap();
        match &p {
            PackedMatrix::QNm(q) => assert_eq!((q.n, q.m), (2, 4)),
            other => panic!("expected qnm, got {}", other.format_label()),
        }
        let unstructured = break_nm(magnitude_prune(&random(15, 8, 24), 0.5).0);
        assert!(PackedMatrix::pack(&unstructured, &PackPolicy::with_format(fmt)).is_err());
    }

    #[test]
    fn effective_bits_follow_the_fig6_accounting() {
        // exactly half the weights survive -> density 0.5 exactly
        let (w, _) = magnitude_prune(&random(16, 8, 32), 0.5);
        let pol = PackPolicy::with_format;
        let f32csr = PackedMatrix::pack(&w, &pol(PackFormat::Csr)).unwrap();
        assert!((f32csr.effective_bits() - 17.0).abs() < 1e-9, "0.5*32 + 1");
        let q4 = PackedMatrix::pack(&w, &pol(PackFormat::QCsr { bits: 4, group: 0 })).unwrap();
        assert!((q4.effective_bits() - 3.0).abs() < 1e-9, "0.5*4 + 1 (the Fig. 6 point)");
        let q8 = PackedMatrix::pack(&w, &pol(PackFormat::QDense { bits: 8, group: 0 })).unwrap();
        assert!((q8.effective_bits() - 5.0).abs() < 1e-9, "0.5*8 + 1");
        let dense = PackedMatrix::pack(&random(17, 4, 8), &pol(PackFormat::Dense)).unwrap();
        assert_eq!(dense.effective_bits(), 32.0);
    }
}
