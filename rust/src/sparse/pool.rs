//! Persistent worker pool for the token-tile kernels.
//!
//! PR 3's driver spawned `std::thread::scope` workers on every kernel call;
//! at serve rates (one call per linear per decode step) the spawn/join cost
//! rivals the math. This pool replaces that: background workers are spawned
//! once and parked on a condvar, and each `run` call publishes one job that
//! every worker (plus the caller, who acts as worker 0) executes until the
//! shared tile queue is drained.
//!
//! Sizing is explicit configuration, not ambient state: a pool is built
//! with a worker count (the CLI validates `SPARSEGPT_THREADS` once at
//! startup and sizes the process-global pool from it), and engines may own
//! private pools with different counts in the same process — the old
//! `num_threads()` `OnceLock`, which froze the first env read forever, is
//! gone. Kernels find the pool through a thread-local installed by
//! [`WorkerPool::install`], falling back to the global pool, so the hot
//! kernels keep their signatures and never touch the environment.
//!
//! The job handed to workers borrows the caller's stack (the tile closure
//! and output spans). That borrow is sound because `run` does not return
//! until every background worker has finished the epoch it claimed: each
//! `run` bumps an epoch counter and sets `pending` to the number of
//! background workers; every worker claims each epoch exactly once and
//! decrements `pending` when done; the caller blocks on `pending == 0`.

use std::cell::{Cell, RefCell};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::Instant;

use crate::sparse::threads::worker_count;

/// Type-erased borrow of the caller's `&(dyn Fn() + Sync)` job. The 'static
/// here is a lie told to the type system only; `run` keeps the real borrow
/// alive until every worker is done with it.
#[derive(Clone, Copy)]
struct Job {
    ptr: *const (dyn Fn() + Sync + 'static),
}
// SAFETY: the pointee is `Sync` (shared by all workers) and outlives every
// use (see the epoch/pending protocol above).
unsafe impl Send for Job {}

struct State {
    job: Option<Job>,
    /// Bumped once per `run`; workers claim each epoch exactly once.
    epoch: u64,
    /// Background workers still running the current epoch.
    pending: usize,
    shutdown: bool,
}

/// Lifetime stats for one worker slot (slot 0 = the calling thread):
/// wall time spent inside jobs and tiles claimed by the steal loops.
/// Relaxed atomics — written by the owning worker, read by snapshots.
#[derive(Default)]
struct WorkerStat {
    busy_ns: AtomicU64,
    tiles: AtomicU64,
}

struct Shared {
    state: Mutex<State>,
    /// Workers park here between jobs.
    work_cv: Condvar,
    /// The submitting caller parks here until `pending == 0`.
    done_cv: Condvar,
    /// Per-slot busy/tile stats, indexed by worker id.
    stats: Vec<WorkerStat>,
}

thread_local! {
    /// This thread's slot in its pool's stats (background workers set
    /// their index once at spawn; everyone else — i.e. callers — is 0).
    static WORKER_ID: Cell<usize> = const { Cell::new(0) };
}

fn worker_loop(shared: Arc<Shared>, me: usize) {
    WORKER_ID.with(|id| id.set(me));
    let mut seen = 0u64;
    loop {
        let job;
        {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch != seen {
                    seen = st.epoch;
                    job = st.job.expect("pool epoch advanced without a job");
                    break;
                }
                st = shared.work_cv.wait(st).unwrap();
            }
        }
        // run outside the lock; the body is a work-stealing loop that
        // returns as soon as the shared tile queue is empty
        let t0 = Instant::now();
        (unsafe { &*job.ptr })();
        shared.stats[me]
            .busy_ns
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        let mut st = shared.state.lock().unwrap();
        st.pending -= 1;
        if st.pending == 0 {
            shared.done_cv.notify_all();
        }
    }
}

struct PoolCore {
    shared: Arc<Shared>,
    /// Spawned background workers (total workers = background + 1 caller).
    background: usize,
    workers: usize,
    /// Serializes concurrent `run` calls (e.g. two engines sharing the
    /// global pool): one job in flight at a time.
    submit: Mutex<()>,
    handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl Drop for PoolCore {
    fn drop(&mut self) {
        self.shared.state.lock().unwrap().shutdown = true;
        self.work_cv_wake();
        for h in self.handles.lock().unwrap().drain(..) {
            let _ = h.join();
        }
    }
}

impl PoolCore {
    fn work_cv_wake(&self) {
        self.shared.work_cv.notify_all();
    }
}

/// A long-lived pool of `workers` threads (the caller counts as one, so
/// `workers - 1` are spawned). Cheap to clone — clones share the workers;
/// the threads shut down when the last clone drops.
#[derive(Clone)]
pub struct WorkerPool {
    core: Arc<PoolCore>,
}

thread_local! {
    /// Pool installed for the current thread (see [`WorkerPool::install`]).
    static CURRENT: RefCell<Option<WorkerPool>> = const { RefCell::new(None) };
}

static GLOBAL: OnceLock<WorkerPool> = OnceLock::new();

impl WorkerPool {
    /// Build a pool with `workers` total workers (min 1 — the caller).
    pub fn new(workers: usize) -> WorkerPool {
        let workers = workers.max(1);
        let shared = Arc::new(Shared {
            state: Mutex::new(State { job: None, epoch: 0, pending: 0, shutdown: false }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
            stats: (0..workers).map(|_| WorkerStat::default()).collect(),
        });
        let mut handles = Vec::with_capacity(workers - 1);
        for i in 1..workers {
            let sh = Arc::clone(&shared);
            let h = std::thread::Builder::new()
                .name(format!("sparse-worker-{i}"))
                .spawn(move || worker_loop(sh, i))
                .expect("spawn sparse worker");
            handles.push(h);
        }
        WorkerPool {
            core: Arc::new(PoolCore {
                shared,
                background: workers - 1,
                workers,
                submit: Mutex::new(()),
                handles: Mutex::new(handles),
            }),
        }
    }

    /// Total worker count, including the calling thread.
    pub fn workers(&self) -> usize {
        self.core.workers
    }

    /// Size the process-global pool explicitly (first call wins; the CLI
    /// does this at startup from the validated `SPARSEGPT_THREADS`).
    /// Returns the global pool.
    pub fn init_global(workers: usize) -> &'static WorkerPool {
        GLOBAL.get_or_init(|| WorkerPool::new(workers))
    }

    /// The process-global pool; lazily sized from `SPARSEGPT_THREADS` if
    /// [`WorkerPool::init_global`] was never called (library/test use).
    /// Panics on an unparseable value — CLI users get the friendly error
    /// from the startup validation first.
    pub fn global() -> &'static WorkerPool {
        GLOBAL.get_or_init(|| {
            WorkerPool::new(worker_count().unwrap_or_else(|e| panic!("{e}")))
        })
    }

    /// Pool the current thread should run kernels on: the innermost
    /// [`WorkerPool::install`] scope, else the global pool.
    pub fn current() -> WorkerPool {
        if let Some(p) = CURRENT.with(|c| c.borrow().clone()) {
            return p;
        }
        WorkerPool::global().clone()
    }

    /// Make this pool the kernel pool for the current thread while `f`
    /// runs (restored on exit, panic-safe; scopes nest). The serve engine
    /// wraps its step loop in this so every kernel under it uses the
    /// engine's own pool.
    pub fn install<R>(&self, f: impl FnOnce() -> R) -> R {
        struct Restore(Option<WorkerPool>);
        impl Drop for Restore {
            fn drop(&mut self) {
                let prev = self.0.take();
                CURRENT.with(|c| *c.borrow_mut() = prev);
            }
        }
        let prev = CURRENT.with(|c| c.borrow_mut().replace(self.clone()));
        let _restore = Restore(prev);
        f()
    }

    /// Run `body` on every worker (background workers plus the calling
    /// thread) until it returns; `body` is expected to drain a shared work
    /// queue. Blocks until all workers have finished. Must not be called
    /// from inside a running job (the pool runs one job at a time).
    pub fn run(&self, body: &(dyn Fn() + Sync)) {
        if self.core.background == 0 {
            let t0 = Instant::now();
            body();
            self.note_busy(0, t0.elapsed().as_nanos() as u64);
            return;
        }
        let _turn = self.core.submit.lock().unwrap();
        let wide: *const (dyn Fn() + Sync) = body;
        {
            let mut st = self.core.shared.state.lock().unwrap();
            // SAFETY: only extends the lifetime; `run` outlives all uses.
            st.job = Some(Job { ptr: unsafe { std::mem::transmute(wide) } });
            st.epoch = st.epoch.wrapping_add(1);
            st.pending = self.core.background;
        }
        self.core.shared.work_cv.notify_all();
        let t0 = Instant::now();
        body(); // the caller is worker 0
        self.note_busy(0, t0.elapsed().as_nanos() as u64);
        let mut st = self.core.shared.state.lock().unwrap();
        while st.pending != 0 {
            st = self.core.shared.done_cv.wait(st).unwrap();
        }
        st.job = None;
    }

    fn note_busy(&self, slot: usize, ns: u64) {
        self.core.shared.stats[slot].busy_ns.fetch_add(ns, Ordering::Relaxed);
    }

    /// Count one stolen tile for the current thread's slot (the kernel
    /// steal loops call this per claimed tile).
    pub fn note_tile(&self) {
        let slot = WORKER_ID.with(|id| id.get()).min(self.core.workers - 1);
        self.core.shared.stats[slot].tiles.fetch_add(1, Ordering::Relaxed);
    }

    /// Lifetime `(busy_ns, tiles)` per worker slot (slot 0 = callers).
    pub fn stats(&self) -> Vec<(u64, u64)> {
        self.core
            .shared
            .stats
            .iter()
            .map(|s| (s.busy_ns.load(Ordering::Relaxed), s.tiles.load(Ordering::Relaxed)))
            .collect()
    }
}

impl std::fmt::Debug for WorkerPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("WorkerPool").field("workers", &self.workers()).finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    /// Drain `n` work items through the pool, counting claims per item.
    fn steal_all(pool: &WorkerPool, n: usize) -> Vec<usize> {
        let next = AtomicUsize::new(0);
        let claims: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        pool.run(&|| loop {
            let i = next.fetch_add(1, Ordering::Relaxed);
            if i >= n {
                break;
            }
            claims[i].fetch_add(1, Ordering::Relaxed);
        });
        claims.into_iter().map(|c| c.into_inner()).collect()
    }

    #[test]
    fn every_item_claimed_exactly_once() {
        for workers in [1, 2, 3, 8] {
            let pool = WorkerPool::new(workers);
            for n in [0, 1, 7, 64] {
                let claims = steal_all(&pool, n);
                assert!(
                    claims.iter().all(|&c| c == 1),
                    "workers={workers} n={n}: {claims:?}"
                );
            }
        }
    }

    #[test]
    fn pool_is_reusable_across_jobs() {
        let pool = WorkerPool::new(4);
        for _ in 0..50 {
            let claims = steal_all(&pool, 16);
            assert!(claims.iter().all(|&c| c == 1));
        }
    }

    #[test]
    fn background_workers_participate() {
        // with enough items, at least one claim must come from a thread
        // other than the caller
        let pool = WorkerPool::new(4);
        let caller = std::thread::current().id();
        let others = AtomicUsize::new(0);
        let gate = std::sync::Barrier::new(4);
        pool.run(&|| {
            gate.wait(); // forces all 4 workers into the job
            if std::thread::current().id() != caller {
                others.fetch_add(1, Ordering::Relaxed);
            }
        });
        assert_eq!(others.into_inner(), 3);
    }

    #[test]
    fn pools_with_different_sizes_coexist() {
        let small = WorkerPool::new(1);
        let big = WorkerPool::new(3);
        assert_eq!(small.workers(), 1);
        assert_eq!(big.workers(), 3);
        assert!(steal_all(&small, 9).iter().all(|&c| c == 1));
        assert!(steal_all(&big, 9).iter().all(|&c| c == 1));
        // interleave to prove neither pool's state leaked into the other
        assert!(steal_all(&small, 3).iter().all(|&c| c == 1));
        assert!(steal_all(&big, 3).iter().all(|&c| c == 1));
    }

    #[test]
    fn install_sets_and_restores_current() {
        let a = WorkerPool::new(2);
        let b = WorkerPool::new(3);
        assert_eq!(a.install(|| WorkerPool::current().workers()), 2);
        // nested installs shadow and restore
        let (inner, outer) = a.install(|| {
            let inner = b.install(|| WorkerPool::current().workers());
            (inner, WorkerPool::current().workers())
        });
        assert_eq!(inner, 3);
        assert_eq!(outer, 2);
        // after all scopes exit, current() falls back to the global pool
        assert_eq!(WorkerPool::current().workers(), WorkerPool::global().workers());
    }

    #[test]
    fn concurrent_submitters_are_serialized() {
        let pool = WorkerPool::new(2);
        std::thread::scope(|s| {
            for _ in 0..4 {
                let pool = pool.clone();
                s.spawn(move || {
                    for _ in 0..20 {
                        let claims = steal_all(&pool, 8);
                        assert!(claims.iter().all(|&c| c == 1));
                    }
                });
            }
        });
    }

    #[test]
    fn stats_track_busy_time_and_tiles() {
        let pool = WorkerPool::new(2);
        assert_eq!(pool.stats(), vec![(0, 0), (0, 0)]);
        pool.run(&|| std::thread::sleep(std::time::Duration::from_millis(2)));
        let stats = pool.stats();
        assert_eq!(stats.len(), 2);
        // both the caller (slot 0) and the background worker ran the job
        assert!(stats.iter().all(|&(busy, _)| busy > 0), "{stats:?}");
        // tile counts only move through note_tile (the kernel steal loops)
        assert!(stats.iter().all(|&(_, tiles)| tiles == 0), "{stats:?}");
        pool.note_tile(); // caller thread books to slot 0
        assert_eq!(pool.stats()[0].1, 1);
    }

    #[test]
    fn dropping_a_clone_keeps_workers_alive() {
        let pool = WorkerPool::new(3);
        let clone = pool.clone();
        drop(pool);
        assert!(steal_all(&clone, 12).iter().all(|&c| c == 1));
    }
}
