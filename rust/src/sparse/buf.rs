//! Section buffers: the owned-or-mapped storage behind every packed stream.
//!
//! [`SectionBuf<T>`] is what a kernel struct field like `row_ptr` or
//! `values` actually holds — either an owned `Vec<T>` (the historical path,
//! still used for in-memory packing and big-endian targets) or a typed view
//! into an [`MmapRegion`] validated at construction. Kernels are oblivious:
//! `Deref<Target = [T]>` makes indexing, slicing and iteration identical on
//! both variants, and the first mutable access silently converts a mapped
//! view into an owned copy (copy-on-write), so tests that poke bytes keep
//! working.
//!
//! Safety rests on three checks done **once**, in [`SectionBuf::mapped`]:
//! the byte offset is `align_of::<T>()`-aligned (region bases are always at
//! least 8-aligned, see `util::mmap`), the element range lies inside the
//! region, and the target is little-endian (on big-endian targets callers
//! must decode into owned buffers — `.spkt` bytes are little-endian).

use std::ops::{Deref, DerefMut};
use std::sync::Arc;

use anyhow::{bail, Result};

use crate::util::mmap::{ByteSource, MmapRegion};

/// Element types that may be reinterpreted directly from `.spkt` bytes:
/// plain-old-data, no padding, no invalid bit patterns, alignment ≤ 8.
///
/// # Safety
/// Implementors must be inhabited by every bit pattern of their size.
pub unsafe trait SectionElem: Copy + PartialEq + std::fmt::Debug + 'static {}
unsafe impl SectionElem for u8 {}
unsafe impl SectionElem for u32 {}
unsafe impl SectionElem for f32 {}

/// Owned vector or validated mapped view — see the module docs.
#[derive(Clone)]
pub enum SectionBuf<T: SectionElem> {
    Owned(Vec<T>),
    Mapped {
        region: Arc<MmapRegion>,
        /// Byte offset of the first element within the region.
        off: usize,
        /// Element (not byte) count.
        len: usize,
    },
}

impl<T: SectionElem> SectionBuf<T> {
    /// Validated zero-copy view of `len` elements at byte offset `off`.
    /// Fails rather than hands out a misaligned, out-of-bounds, or
    /// wrong-endian view.
    pub fn mapped(region: Arc<MmapRegion>, off: usize, len: usize) -> Result<Self> {
        if !cfg!(target_endian = "little") {
            bail!("mapped sections require a little-endian target");
        }
        let size = std::mem::size_of::<T>();
        if off % std::mem::align_of::<T>() != 0 {
            bail!("section offset {off} is not aligned for {}", std::any::type_name::<T>());
        }
        let Some(bytes) = len.checked_mul(size).and_then(|b| b.checked_add(off)) else {
            bail!("section extent overflows: off {off} + {len} elems");
        };
        if bytes > region.len() {
            bail!("section [{off}, {bytes}) exceeds region of {} bytes", region.len());
        }
        Ok(SectionBuf::Mapped { region, off, len })
    }

    /// True when the elements are served from mapped pages.
    pub fn is_mapped(&self) -> bool {
        match self {
            SectionBuf::Owned(_) => false,
            SectionBuf::Mapped { region, .. } => region.is_mapped(),
        }
    }

    /// Bytes of this buffer currently backed by mapped pages (0 when owned).
    pub fn mapped_bytes(&self) -> u64 {
        if self.is_mapped() {
            (self.len() * std::mem::size_of::<T>()) as u64
        } else {
            0
        }
    }

    /// Total bytes of element payload, however it is backed.
    pub fn payload_bytes(&self) -> u64 {
        (self.len() * std::mem::size_of::<T>()) as u64
    }
}

impl<T: SectionElem> Deref for SectionBuf<T> {
    type Target = [T];
    fn deref(&self) -> &[T] {
        match self {
            SectionBuf::Owned(v) => v,
            SectionBuf::Mapped { region, off, len } => {
                // SAFETY: alignment, bounds and endianness were validated in
                // `mapped()`; the region is immutable and outlives the view
                // through the Arc.
                unsafe {
                    std::slice::from_raw_parts(
                        region.bytes().as_ptr().add(*off) as *const T,
                        *len,
                    )
                }
            }
        }
    }
}

impl<T: SectionElem> DerefMut for SectionBuf<T> {
    /// Copy-on-write: the first mutable access to a mapped view detaches it
    /// into an owned copy (mapped pages are PROT_READ).
    fn deref_mut(&mut self) -> &mut [T] {
        if let SectionBuf::Mapped { .. } = self {
            *self = SectionBuf::Owned((**self).to_vec());
        }
        match self {
            SectionBuf::Owned(v) => v,
            SectionBuf::Mapped { .. } => unreachable!("detached above"),
        }
    }
}

impl<T: SectionElem> From<Vec<T>> for SectionBuf<T> {
    fn from(v: Vec<T>) -> Self {
        SectionBuf::Owned(v)
    }
}

impl<T: SectionElem> PartialEq for SectionBuf<T> {
    fn eq(&self, other: &Self) -> bool {
        **self == **other
    }
}

impl<T: SectionElem> std::fmt::Debug for SectionBuf<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        (**self).fmt(f)
    }
}

impl<'a, T: SectionElem> IntoIterator for &'a SectionBuf<T> {
    type Item = &'a T;
    type IntoIter = std::slice::Iter<'a, T>;
    fn into_iter(self) -> Self::IntoIter {
        (**self).iter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn region_of(words: &[u32]) -> Arc<MmapRegion> {
        let mut bytes = Vec::with_capacity(words.len() * 4);
        for w in words {
            bytes.extend_from_slice(&w.to_le_bytes());
        }
        Arc::new(MmapRegion::from_bytes(&bytes))
    }

    #[test]
    fn mapped_view_reads_like_a_slice() {
        let r = region_of(&[7, 11, 13, 17]);
        let b = SectionBuf::<u32>::mapped(r, 4, 3).unwrap();
        assert_eq!(&b[..], &[11, 13, 17]);
        assert_eq!(b.len(), 3);
        assert_eq!(b.iter().copied().sum::<u32>(), 41);
        let mut seen = Vec::new();
        for v in &b {
            seen.push(*v);
        }
        assert_eq!(seen, vec![11, 13, 17]);
    }

    #[test]
    fn misaligned_or_oob_views_are_rejected() {
        let r = region_of(&[1, 2, 3]);
        assert!(SectionBuf::<u32>::mapped(r.clone(), 2, 1).is_err(), "misaligned");
        assert!(SectionBuf::<u32>::mapped(r.clone(), 4, 3).is_err(), "past the end");
        assert!(SectionBuf::<u32>::mapped(r, usize::MAX - 2, 2).is_err(), "overflow");
    }

    #[test]
    fn mutation_detaches_into_owned_copy() {
        let r = region_of(&[5, 6, 7]);
        let mut b = SectionBuf::<u32>::mapped(r.clone(), 0, 3).unwrap();
        b[1] = 99;
        assert_eq!(&b[..], &[5, 99, 7]);
        assert!(!b.is_mapped(), "mutated buffer must be owned");
        // the region itself is untouched
        let fresh = SectionBuf::<u32>::mapped(r, 0, 3).unwrap();
        assert_eq!(&fresh[..], &[5, 6, 7]);
    }

    #[test]
    fn owned_and_mapped_compare_equal() {
        let r = region_of(&[1, 2]);
        let m = SectionBuf::<u32>::mapped(r, 0, 2).unwrap();
        let o: SectionBuf<u32> = vec![1, 2].into();
        assert_eq!(m, o);
        assert_eq!(m.payload_bytes(), 8);
        assert_eq!(o.mapped_bytes(), 0);
    }
}
