//! Dense row-major f32 tensor + f64 linear algebra substrate.
//!
//! The heavy model math runs inside XLA; this tensor type exists for the
//! coordinator's bookkeeping (weight slices, masks, Hessian accumulators),
//! the pure-Rust reference solvers, and the CPU sparse inference engine.

pub mod linalg;

use anyhow::{bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape, data: vec![0.0; n] }
    }

    pub fn ones(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape, data: vec![1.0; n] }
    }

    pub fn scalar(v: f32) -> Tensor {
        Tensor { shape: vec![], data: vec![v] }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    pub fn rows(&self) -> usize {
        assert_eq!(self.shape.len(), 2);
        self.shape[0]
    }

    pub fn cols(&self) -> usize {
        assert_eq!(self.shape.len(), 2);
        self.shape[1]
    }

    #[inline]
    pub fn at2(&self, r: usize, c: usize) -> f32 {
        debug_assert_eq!(self.shape.len(), 2);
        self.data[r * self.shape[1] + c]
    }

    #[inline]
    pub fn set2(&mut self, r: usize, c: usize, v: f32) {
        debug_assert_eq!(self.shape.len(), 2);
        self.data[r * self.shape[1] + c] = v;
    }

    pub fn row(&self, r: usize) -> &[f32] {
        let c = self.cols();
        &self.data[r * c..(r + 1) * c]
    }

    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        let c = self.cols();
        &mut self.data[r * c..(r + 1) * c]
    }

    pub fn reshape(mut self, shape: Vec<usize>) -> Result<Tensor> {
        if shape.iter().product::<usize>() != self.data.len() {
            bail!("reshape {:?} -> {:?}: element count mismatch", self.shape, shape);
        }
        self.shape = shape;
        Ok(self)
    }

    pub fn transpose2(&self) -> Tensor {
        let (r, c) = (self.rows(), self.cols());
        let mut out = vec![0.0f32; r * c];
        for i in 0..r {
            for j in 0..c {
                out[j * r + i] = self.data[i * c + j];
            }
        }
        Tensor::new(vec![c, r], out)
    }

    /// Dense matmul (blocked i-k-j), used by the sparse engine's baseline
    /// and the reference solvers. Not the model hot path (that's XLA).
    pub fn matmul(&self, rhs: &Tensor) -> Tensor {
        let (m, k) = (self.rows(), self.cols());
        let (k2, n) = (rhs.rows(), rhs.cols());
        assert_eq!(k, k2, "matmul dim mismatch");
        let mut out = vec![0.0f32; m * n];
        const BK: usize = 64;
        for kb in (0..k).step_by(BK) {
            let ke = (kb + BK).min(k);
            for i in 0..m {
                let arow = &self.data[i * k..(i + 1) * k];
                let orow = &mut out[i * n..(i + 1) * n];
                for kk in kb..ke {
                    let a = arow[kk];
                    if a == 0.0 {
                        continue;
                    }
                    let brow = &rhs.data[kk * n..(kk + 1) * n];
                    for j in 0..n {
                        orow[j] += a * brow[j];
                    }
                }
            }
        }
        Tensor::new(vec![m, n], out)
    }

    pub fn fro_norm_sq(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum()
    }

    /// Fraction of exactly-zero entries.
    pub fn sparsity(&self) -> f64 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.data.iter().filter(|&&x| x == 0.0).count() as f64 / self.data.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_small() {
        let a = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let b = Tensor::new(vec![3, 2], vec![7., 8., 9., 10., 11., 12.]);
        let c = a.matmul(&b);
        assert_eq!(c.data(), &[58., 64., 139., 154.]);
    }

    #[test]
    fn matmul_matches_naive_random() {
        let mut rng = crate::util::prng::Rng::new(1);
        let (m, k, n) = (13, 37, 9);
        let a = Tensor::new(vec![m, k], (0..m * k).map(|_| rng.normal_f32()).collect());
        let b = Tensor::new(vec![k, n], (0..k * n).map(|_| rng.normal_f32()).collect());
        let c = a.matmul(&b);
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0f64;
                for kk in 0..k {
                    s += a.at2(i, kk) as f64 * b.at2(kk, j) as f64;
                }
                assert!((c.at2(i, j) as f64 - s).abs() < 1e-3);
            }
        }
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = crate::util::prng::Rng::new(2);
        let a = Tensor::new(vec![5, 7], (0..35).map(|_| rng.normal_f32()).collect());
        assert_eq!(a.transpose2().transpose2(), a);
    }

    #[test]
    fn sparsity_counts_zeros() {
        let t = Tensor::new(vec![2, 2], vec![0.0, 1.0, 0.0, 2.0]);
        assert_eq!(t.sparsity(), 0.5);
    }

    #[test]
    fn reshape_checks_count() {
        let t = Tensor::zeros(vec![2, 3]);
        assert!(t.clone().reshape(vec![3, 2]).is_ok());
        assert!(t.reshape(vec![4, 2]).is_err());
    }
}
