//! f64 dense linear algebra for the reference solvers and verification.
//!
//! The production Hessian-preparation chain runs inside XLA (the
//! `hessian_prep_<dim>` artifact, see `python/compile/linalg_jnp.py`); this
//! module provides the same chain in f64 for cross-checking, for the exact
//! per-row OBS reconstruction of the Fig-11 experiment, and for small
//! utilities (power iteration for the AdaPrune step size).

/// Column-major-free, simple row-major (n x n) f64 matrix helpers.
#[derive(Clone, Debug)]
pub struct Mat {
    pub n: usize,
    pub a: Vec<f64>,
}

impl Mat {
    pub fn zeros(n: usize) -> Mat {
        Mat { n, a: vec![0.0; n * n] }
    }

    pub fn eye(n: usize) -> Mat {
        let mut m = Mat::zeros(n);
        for i in 0..n {
            m.a[i * n + i] = 1.0;
        }
        m
    }

    pub fn from_f32(n: usize, data: &[f32]) -> Mat {
        assert_eq!(data.len(), n * n);
        Mat { n, a: data.iter().map(|&x| x as f64).collect() }
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f64 {
        self.a[i * self.n + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        self.a[i * self.n + j] = v;
    }

    pub fn to_f32(&self) -> Vec<f32> {
        self.a.iter().map(|&x| x as f32).collect()
    }

    pub fn transpose(&self) -> Mat {
        let n = self.n;
        let mut t = Mat::zeros(n);
        for i in 0..n {
            for j in 0..n {
                t.a[j * n + i] = self.a[i * n + j];
            }
        }
        t
    }

    pub fn matmul(&self, rhs: &Mat) -> Mat {
        let n = self.n;
        assert_eq!(n, rhs.n);
        let mut out = Mat::zeros(n);
        for i in 0..n {
            for k in 0..n {
                let aik = self.a[i * n + k];
                if aik == 0.0 {
                    continue;
                }
                let brow = &rhs.a[k * n..(k + 1) * n];
                let orow = &mut out.a[i * n..(i + 1) * n];
                for j in 0..n {
                    orow[j] += aik * brow[j];
                }
            }
        }
        out
    }
}

/// In-place lower Cholesky: A = L L^T. Returns None if not SPD.
pub fn cholesky_lower(a: &Mat) -> Option<Mat> {
    let n = a.n;
    let mut l = Mat::zeros(n);
    for i in 0..n {
        for j in 0..=i {
            let mut s = a.at(i, j);
            for k in 0..j {
                s -= l.at(i, k) * l.at(j, k);
            }
            if i == j {
                if s <= 0.0 {
                    return None;
                }
                l.set(i, j, s.sqrt());
            } else {
                l.set(i, j, s / l.at(j, j));
            }
        }
    }
    Some(l)
}

/// Inverse of a lower-triangular matrix by forward substitution.
pub fn tril_inverse(l: &Mat) -> Mat {
    let n = l.n;
    let mut x = Mat::zeros(n);
    for j in 0..n {
        x.set(j, j, 1.0 / l.at(j, j));
        for i in j + 1..n {
            let mut s = 0.0;
            for k in j..i {
                s += l.at(i, k) * x.at(k, j);
            }
            x.set(i, j, -s / l.at(i, i));
        }
    }
    x
}

/// Add `damp * mean(diag)` to the diagonal (the paper's App-A dampening).
pub fn dampen(h: &Mat, damp: f64) -> Mat {
    let n = h.n;
    let mut mean = (0..n).map(|i| h.at(i, i)).sum::<f64>() / n as f64;
    if mean <= 0.0 {
        mean = 1.0;
    }
    let mut out = h.clone();
    for i in 0..n {
        out.a[i * n + i] += damp * mean;
    }
    out
}

/// The full SparseGPT Hessian chain: H -> upper factor U with
/// (H + damp*mean(diag)*I)^{-1} = U^T U. Mirrors `hessian_prep_fn`.
pub fn hessian_prep(h: &Mat, damp: f64) -> Option<Mat> {
    let hd = dampen(h, damp);
    let l = cholesky_lower(&hd)?;
    let linv = tril_inverse(&l);
    let hinv = linv.transpose().matmul(&linv);
    let c = cholesky_lower(&hinv)?;
    Some(c.transpose())
}

/// Solve A x = b for SPD A via Cholesky (used by the exact OBS solver).
pub fn spd_solve(a: &Mat, b: &[f64]) -> Option<Vec<f64>> {
    let n = a.n;
    assert_eq!(b.len(), n);
    let l = cholesky_lower(a)?;
    // forward: L y = b
    let mut y = vec![0.0; n];
    for i in 0..n {
        let mut s = b[i];
        for k in 0..i {
            s -= l.at(i, k) * y[k];
        }
        y[i] = s / l.at(i, i);
    }
    // backward: L^T x = y
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut s = y[i];
        for k in i + 1..n {
            s -= l.at(k, i) * x[k];
        }
        x[i] = s / l.at(i, i);
    }
    Some(x)
}

/// Largest-eigenvalue estimate by power iteration (for the AdaPrune lr).
pub fn lambda_max(h: &Mat, iters: usize, seed: u64) -> f64 {
    let n = h.n;
    let mut rng = crate::util::prng::Rng::new(seed);
    let mut v: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let mut lam = 0.0;
    for _ in 0..iters {
        let mut w = vec![0.0; n];
        for i in 0..n {
            let row = &h.a[i * n..(i + 1) * n];
            w[i] = row.iter().zip(&v).map(|(a, b)| a * b).sum();
        }
        lam = w.iter().map(|x| x * x).sum::<f64>().sqrt();
        if lam == 0.0 {
            return 0.0;
        }
        for x in &mut w {
            *x /= lam;
        }
        v = w;
    }
    lam
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn random_spd(n: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        let rows = 2 * n;
        let x: Vec<f64> = (0..rows * n).map(|_| rng.normal()).collect();
        let mut h = Mat::zeros(n);
        for r in 0..rows {
            for i in 0..n {
                for j in 0..n {
                    h.a[i * n + j] += x[r * n + i] * x[r * n + j];
                }
            }
        }
        h
    }

    #[test]
    fn cholesky_reconstructs() {
        let h = random_spd(24, 1);
        let l = cholesky_lower(&h).unwrap();
        let llt = l.matmul(&l.transpose());
        for i in 0..h.n * h.n {
            assert!((llt.a[i] - h.a[i]).abs() < 1e-8 * (1.0 + h.a[i].abs()));
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let mut m = Mat::eye(3);
        m.set(2, 2, -1.0);
        assert!(cholesky_lower(&m).is_none());
    }

    #[test]
    fn tril_inverse_identity() {
        let h = random_spd(16, 2);
        let l = cholesky_lower(&h).unwrap();
        let li = tril_inverse(&l);
        let prod = li.matmul(&l);
        for i in 0..16 {
            for j in 0..16 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((prod.at(i, j) - want).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn hessian_prep_factor_property() {
        // U^T U must equal (H + damp mean(diag) I)^{-1}
        let h = random_spd(20, 3);
        let u = hessian_prep(&h, 0.01).unwrap();
        let hinv = u.transpose().matmul(&u);
        let hd = dampen(&h, 0.01);
        let prod = hinv.matmul(&hd);
        for i in 0..20 {
            for j in 0..20 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((prod.at(i, j) - want).abs() < 1e-7, "{} {}", i, j);
            }
        }
        // upper-triangular
        for i in 0..20 {
            for j in 0..i {
                assert_eq!(u.at(i, j), 0.0);
            }
        }
    }

    #[test]
    fn spd_solve_matches() {
        let h = random_spd(12, 4);
        let mut rng = Rng::new(5);
        let x_true: Vec<f64> = (0..12).map(|_| rng.normal()).collect();
        let mut b = vec![0.0; 12];
        for i in 0..12 {
            b[i] = (0..12).map(|j| h.at(i, j) * x_true[j]).sum();
        }
        let x = spd_solve(&h, &b).unwrap();
        for i in 0..12 {
            assert!((x[i] - x_true[i]).abs() < 1e-6);
        }
    }

    #[test]
    fn lambda_max_close_to_true() {
        // diag matrix: lambda_max known exactly
        let mut m = Mat::zeros(8);
        for i in 0..8 {
            m.set(i, i, (i + 1) as f64);
        }
        let lam = lambda_max(&m, 200, 0);
        assert!((lam - 8.0).abs() < 1e-6, "{lam}");
    }
}
