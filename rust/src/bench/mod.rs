//! Shared support for the benchmark binaries (one per paper table/figure)
//! and the examples: variant pruning, evaluation over all corpora, report
//! plumbing. Benches run via `cargo bench` with `harness = false` (criterion
//! is unavailable offline); each prints a paper-shaped table and saves
//! txt/csv copies under `reports/`.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::coordinator::{PruneMethod, PruneOptions, PruneOutcome, SkipSpec};
use crate::eval::perplexity;
use crate::harness::{Workspace, DEFAULT_CALIB_SEGMENTS};
use crate::model::layout::FlatParams;

/// Env-tunable knobs so heavy benches can be scaled to the machine:
///   SPARSEGPT_BENCH_CONFIGS   comma list (default per bench)
///   SPARSEGPT_BENCH_SEGMENTS  eval segments per dataset (default 128)
///   SPARSEGPT_BENCH_CALIB     calibration segments (default 128)
pub fn env_configs(default: &[&str]) -> Vec<String> {
    match std::env::var("SPARSEGPT_BENCH_CONFIGS") {
        Ok(v) if !v.is_empty() => v.split(',').map(|s| s.trim().to_string()).collect(),
        _ => default.iter().map(|s| s.to_string()).collect(),
    }
}

pub fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

pub fn eval_segments() -> usize {
    env_usize("SPARSEGPT_BENCH_SEGMENTS", 128)
}

pub fn calib_segments() -> usize {
    env_usize("SPARSEGPT_BENCH_CALIB", DEFAULT_CALIB_SEGMENTS)
}

/// Prune a fresh copy of `dense` with `method` and default options.
pub fn prune_variant(
    ws: &Workspace,
    dense: &FlatParams,
    method: PruneMethod,
) -> Result<PruneOutcome> {
    prune_variant_opts(
        ws,
        dense,
        PruneOptions { method, ..Default::default() },
        calib_segments(),
        0,
    )
}

pub fn prune_variant_opts(
    ws: &Workspace,
    dense: &FlatParams,
    opts: PruneOptions,
    n_calib: usize,
    calib_seed: u64,
) -> Result<PruneOutcome> {
    // route through the api layer's single prune entry point (silently)
    let chunks = ws.calib_chunks(&dense.cfg, n_calib, calib_seed)?;
    let r = crate::api::prune_params(
        ws,
        &dense.cfg.name,
        dense.clone(),
        &chunks,
        &opts,
        &mut crate::api::NullSink,
    )?;
    Ok(PruneOutcome {
        params: r.params,
        reports: r.matrices,
        total_secs: r.total_secs,
        hessian_secs: r.hessian_secs,
        solver_secs: r.solver_secs,
        propagate_secs: r.propagate_secs,
    })
}

/// Perplexity on every eval corpus; key -> ppl.
pub fn eval_all(ws: &Workspace, params: &FlatParams) -> Result<BTreeMap<String, f64>> {
    let segs = eval_segments();
    let mut out = BTreeMap::new();
    for (name, ds) in ws.eval_datasets()? {
        out.insert(name, perplexity(&ws.rt, params, &ds, segs)?.ppl);
    }
    Ok(out)
}

/// Perplexity on one corpus.
pub fn eval_one(ws: &Workspace, params: &FlatParams, ds_name: &str) -> Result<f64> {
    let ds = ws.dataset(ds_name)?;
    Ok(perplexity(&ws.rt, params, &ds, eval_segments())?.ppl)
}

/// Load the trained model for `config` or explain how to get one.
pub fn require_model(ws: &Workspace, config: &str) -> Result<FlatParams> {
    ws.load_model(config)
}

/// Common skeleton: print + persist a report table.
pub fn finish(ws: &Workspace, table: &crate::eval::report::Table, stem: &str) -> Result<()> {
    print!("{}", table.render());
    table.save(&ws.report_dir, stem)?;
    println!("(saved reports/{stem}.txt + .csv)\n");
    Ok(())
}

pub fn default_skip() -> SkipSpec {
    SkipSpec::None
}
