//! Appendix A ablations on the `small` config at 50% unstructured sparsity:
//!   Figure 8 — number of calibration segments (powers of two),
//!   Figure 9 — Hessian dampening multiplier (powers of ten),
//!   Figure 10 — adaptive mask-selection blocksize Bs,
//!   plus the 5-seed calibration-sensitivity check (mean ± std).

use anyhow::Result;
use sparsegpt::bench::{env_configs, eval_one, finish, prune_variant_opts};
use sparsegpt::coordinator::{PruneMethod, PruneOptions};
use sparsegpt::eval::report::{fmt_ppl, Table};
use sparsegpt::harness::Workspace;
use sparsegpt::solver::sparsegpt_ref::Pattern;
use sparsegpt::util::timer::Stats;

fn main() -> Result<()> {
    let ws = Workspace::open()?;
    let config = env_configs(&["small"]).remove(0);
    let dense = ws.load_model(&config)?;
    let sgpt =
        PruneMethod::SparseGpt { pattern: Pattern::Unstructured(0.5), quant_bits: None };

    // Figure 8: calibration samples
    let mut t8 = Table::new(&format!("Figure 8 (calibration samples, {config})"), &["segments", "wiki ppl"]);
    for n in [8usize, 32, 128] {
        let out = prune_variant_opts(
            &ws,
            &dense,
            PruneOptions { method: sgpt.clone(), ..Default::default() },
            n,
            0,
        )?;
        let ppl = eval_one(&ws, &out.params, "synth-wiki")?;
        println!("calib {n}: {}", fmt_ppl(ppl));
        t8.row(vec![n.to_string(), fmt_ppl(ppl)]);
    }
    finish(&ws, &t8, "fig8_calibration")?;

    // Figure 9: dampening
    let mut t9 = Table::new(&format!("Figure 9 (Hessian dampening, {config})"), &["damp", "wiki ppl"]);
    for damp in [1e-3, 1e-2, 1e-1, 1.0] {
        let out = prune_variant_opts(
            &ws,
            &dense,
            PruneOptions { method: sgpt.clone(), damp, ..Default::default() },
            sparsegpt::bench::calib_segments(),
            0,
        )?;
        let ppl = eval_one(&ws, &out.params, "synth-wiki")?;
        println!("damp {damp:.0e}: {}", fmt_ppl(ppl));
        t9.row(vec![format!("{damp:.0e}"), fmt_ppl(ppl)]);
    }
    finish(&ws, &t9, "fig9_dampening")?;

    // Figure 10: mask-selection blocksize (Bs > layer width clamps down)
    let mut t10 = Table::new(&format!("Figure 10 (mask blocksize, {config})"), &["Bs", "wiki ppl"]);
    for bs in [1usize, 64, 128, 1024] {
        let method = if bs == 128 {
            sgpt.clone() // the production Pallas path
        } else {
            PruneMethod::SparseGptBs { sparsity: 0.5, mask_blocksize: bs }
        };
        let out = prune_variant_opts(
            &ws,
            &dense,
            PruneOptions { method, ..Default::default() },
            sparsegpt::bench::calib_segments(),
            0,
        )?;
        let ppl = eval_one(&ws, &out.params, "synth-wiki")?;
        println!("Bs {bs}: {}", fmt_ppl(ppl));
        t10.row(vec![bs.to_string(), fmt_ppl(ppl)]);
    }
    finish(&ws, &t10, "fig10_blocksize")?;

    // App A: sensitivity to calibration seed (5 runs)
    let mut ppls = Vec::new();
    for seed in 0..3u64 {
        let out = prune_variant_opts(
            &ws,
            &dense,
            PruneOptions { method: sgpt.clone(), ..Default::default() },
            sparsegpt::bench::calib_segments(),
            seed,
        )?;
        let ppl = eval_one(&ws, &out.params, "synth-wiki")?;
        println!("seed {seed}: {}", fmt_ppl(ppl));
        ppls.push(ppl);
    }
    let s = Stats::from(ppls);
    let mut ts = Table::new(
        &format!("App A seed sensitivity ({config}, 3 seeds)"),
        &["mean ppl", "std", "min", "max"],
    );
    ts.row(vec![
        format!("{:.3}", s.mean),
        format!("{:.3}", s.std),
        format!("{:.3}", s.min),
        format!("{:.3}", s.max),
    ]);
    finish(&ws, &ts, "appA_seed_sensitivity")
}
