//! Table 8: per-layer 2:4 structured matmul speedups at the three matrix
//! shapes of the flagship model (the paper uses OPT-175B's Q/K/V/Out, FC1,
//! FC2 at 2048 tokens on CUTLASS vs cuBLAS and reports 1.79x/1.67x/1.54x;
//! we use the `large` config's scaled shapes on the CPU engine).

use anyhow::Result;
use sparsegpt::bench::{env_configs, env_usize, finish};
use sparsegpt::eval::report::Table;
use sparsegpt::harness::Workspace;
use sparsegpt::solver::magnitude::magnitude_prune_nm;
use sparsegpt::sparse::{dense_layer, NmMatrix};
use sparsegpt::tensor::Tensor;
use sparsegpt::util::prng::Rng;
use sparsegpt::util::timer::bench_fn;

fn main() -> Result<()> {
    let ws = Workspace::open()?;
    let config = env_configs(&["large"]).remove(0);
    let cfg = ws.config(&config)?;
    let tokens = env_usize("SPARSEGPT_BENCH_TOKENS", 2048);
    let mut rng = Rng::new(0);

    let shapes = [
        ("Q/K/V/Out", cfg.d, cfg.d),
        ("FC1", cfg.ffn, cfg.d),
        ("FC2", cfg.d, cfg.ffn),
    ];
    let mut table = Table::new(
        &format!("Table 8 (2:4 matmul speedup, {config} shapes, {tokens} tokens)"),
        &["weight", "dense ms", "2:4 ms", "speedup"],
    );
    for (label, r, c) in shapes {
        let w = Tensor::new(vec![r, c], (0..r * c).map(|_| rng.normal_f32()).collect());
        let (w24, _) = magnitude_prune_nm(&w, 2, 4);
        let nm = NmMatrix::from_dense(&w24, 2, 4)?;
        let x = Tensor::new(vec![tokens, c], (0..tokens * c).map(|_| rng.normal_f32()).collect());
        let d = bench_fn(1, 3, || {
            std::hint::black_box(dense_layer(&x, &w));
        });
        let s = bench_fn(1, 3, || {
            std::hint::black_box(nm.layer(&x));
        });
        let speedup = d.median / s.median;
        println!("{label}: dense {:.1}ms 2:4 {:.1}ms ({speedup:.2}x)", d.median * 1e3, s.median * 1e3);
        table.row(vec![
            label.to_string(),
            format!("{:.1}", d.median * 1e3),
            format!("{:.1}", s.median * 1e3),
            format!("{speedup:.2}x"),
        ]);
    }
    finish(&ws, &table, "table8_24_matmul")
}
