//! The paper's runtime claim (Sec. 4: SparseGPT prunes 175B in ~4h while
//! AdaPrune needs hours for 1.3B; complexity O(d_col^3 + d_row d_col^2) vs
//! exact O(d_row d_col^3)): per-layer solver wall-clock across the family's
//! widths for SparseGPT (HLO artifact), AdaPrune (GD reconstruction
//! artifact), the Rust reference solver, and exact reconstruction (smallest
//! shapes only), plus the fitted scaling exponent of the SparseGPT path.

use anyhow::Result;
use sparsegpt::bench::finish;
use sparsegpt::eval::report::Table;
use sparsegpt::harness::Workspace;
use sparsegpt::runtime::ArgValue;
use sparsegpt::solver::exact::exact_reconstruction;
use sparsegpt::solver::hessian::dampened_hinv_chol_f64;
use sparsegpt::solver::magnitude::magnitude_prune;
use sparsegpt::solver::sparsegpt_ref::{ref_sparsegpt, Pattern};
use sparsegpt::tensor::linalg::{dampen, Mat};
use sparsegpt::tensor::Tensor;
use sparsegpt::util::prng::Rng;
use sparsegpt::util::timer::Timer;

fn main() -> Result<()> {
    let ws = Workspace::open()?;
    let mut rng = Rng::new(0);
    let dims = [64usize, 128, 256, 512, 768];
    let mut table = Table::new(
        "Runtime scaling (per (d,d) layer, seconds)",
        &["d", "sparsegpt(hlo)", "rust-ref", "adaprune(hlo)", "exact"],
    );
    let mut log_pairs = Vec::new();

    for d in dims {
        let w = Tensor::new(vec![d, d], (0..d * d).map(|_| rng.normal_f32()).collect());
        let n = 2 * d;
        let x = Tensor::new(vec![n, d], (0..n * d).map(|_| rng.normal_f32()).collect());
        let h = x.transpose2().matmul(&x);
        let hc = dampened_hinv_chol_f64(&h, 0.01).unwrap();

        // HLO solver (compile excluded — it is a one-time cost per shape)
        let name = format!("sparsegpt_{d}x{d}");
        ws.rt.prepare(&name)?;
        let t = Timer::start();
        let _ = ws.rt.run(
            &name,
            &[
                ArgValue::F32(w.data()),
                ArgValue::F32(hc.data()),
                ArgValue::Scalar(0.5),
                ArgValue::Scalar(0.0),
            ],
        )?;
        let t_hlo = t.secs();
        log_pairs.push(((d as f64).ln(), t_hlo.ln()));

        // pure-Rust reference
        let t = Timer::start();
        let _ = ref_sparsegpt(&w, &hc, Pattern::Unstructured(0.5), 0, 128);
        let t_ref = t.secs();

        // AdaPrune artifact (256 GD steps)
        let aname = format!("adaprune_{d}x{d}");
        let t_ada = if ws.rt.has_artifact(&aname) {
            ws.rt.prepare(&aname)?;
            let (_, mask) = magnitude_prune(&w, 0.5);
            let t = Timer::start();
            let _ = ws.rt.run(
                &aname,
                &[
                    ArgValue::F32(w.data()),
                    ArgValue::F32(mask.data()),
                    ArgValue::F32(h.data()),
                    ArgValue::Scalar(1e-4),
                ],
            )?;
            format!("{:.3}", t.secs())
        } else {
            "-".into()
        };

        // exact reconstruction (d <= 128 only; O(d^4) beyond that)
        let t_exact = if d <= 128 {
            let hd_m = dampen(&Mat::from_f32(d, h.data()), 0.01);
            let hd = Tensor::new(vec![d, d], hd_m.to_f32());
            let (_, mask) = magnitude_prune(&w, 0.5);
            let t = Timer::start();
            let _ = exact_reconstruction(&w, &mask, &hd, None)?;
            format!("{:.3}", t.secs())
        } else {
            "-".into()
        };

        println!("d={d}: hlo {t_hlo:.3}s ref {t_ref:.3}s ada {t_ada} exact {t_exact}");
        table.row(vec![
            d.to_string(),
            format!("{t_hlo:.3}"),
            format!("{t_ref:.3}"),
            t_ada,
            t_exact,
        ]);
    }

    // least-squares exponent of t ~ d^k for the HLO path
    let n = log_pairs.len() as f64;
    let sx: f64 = log_pairs.iter().map(|p| p.0).sum();
    let sy: f64 = log_pairs.iter().map(|p| p.1).sum();
    let sxx: f64 = log_pairs.iter().map(|p| p.0 * p.0).sum();
    let sxy: f64 = log_pairs.iter().map(|p| p.0 * p.1).sum();
    let k = (n * sxy - sx * sy) / (n * sxx - sx * sx);
    table.row(vec![
        "fit".into(),
        format!("~d^{k:.2}"),
        "-".into(),
        "-".into(),
        "-".into(),
    ]);
    println!("sparsegpt(hlo) scaling exponent: {k:.2} (paper predicts <= 3)");
    finish(&ws, &table, "runtime_scaling")
}
