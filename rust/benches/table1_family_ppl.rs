//! Tables 1, 3, 4 + Figure 2: perplexity across the model family for
//! dense / magnitude-50% / AdaPrune-50% / SparseGPT-{50%, 4:8, 2:4}, on the
//! three eval corpora (synth-wiki ~ raw-WikiText2, synth-ptb ~ PTB,
//! synth-c4-val ~ the C4 subset).
//!
//! AdaPrune runs on the two smallest configs only, mirroring the paper
//! (which only runs it up to 1.3B because of its cost).
//!
//! Env knobs: SPARSEGPT_BENCH_CONFIGS, SPARSEGPT_BENCH_SEGMENTS,
//! SPARSEGPT_BENCH_CALIB.

use anyhow::Result;
use sparsegpt::bench::{env_configs, eval_all, finish, prune_variant};
use sparsegpt::coordinator::PruneMethod;
use sparsegpt::eval::report::{fmt_ppl, Table};
use sparsegpt::harness::Workspace;
use sparsegpt::solver::sparsegpt_ref::Pattern;

fn main() -> Result<()> {
    let ws = Workspace::open()?;
    let configs = env_configs(&["nano", "micro", "small", "medium"]);
    let adaprune_configs = ["nano", "micro"];

    let mut rows: Vec<(String, String, std::collections::BTreeMap<String, f64>)> = Vec::new();
    for config in &configs {
        let dense = match ws.load_model(config) {
            Ok(p) => p,
            Err(e) => {
                eprintln!("skipping {config}: {e:#}");
                continue;
            }
        };
        println!("== {config} ==");
        rows.push((config.clone(), "dense".into(), eval_all(&ws, &dense)?));

        let mut methods: Vec<(&str, PruneMethod)> = vec![
            ("magnitude-50%", PruneMethod::Magnitude { pattern: Pattern::Unstructured(0.5) }),
            (
                "sparsegpt-50%",
                PruneMethod::SparseGpt { pattern: Pattern::Unstructured(0.5), quant_bits: None },
            ),
            (
                "sparsegpt-4:8",
                PruneMethod::SparseGpt { pattern: Pattern::NM(4, 8), quant_bits: None },
            ),
            (
                "sparsegpt-2:4",
                PruneMethod::SparseGpt { pattern: Pattern::NM(2, 4), quant_bits: None },
            ),
        ];
        if adaprune_configs.contains(&config.as_str()) {
            methods.insert(1, ("adaprune-50%", PruneMethod::AdaPrune { sparsity: 0.5 }));
        }
        for (label, method) in methods {
            let out = prune_variant(&ws, &dense, method)?;
            let ppl = eval_all(&ws, &out.params)?;
            println!(
                "  {label}: sparsity {:.3}, {:.0}s, wiki {}",
                out.overall_sparsity(),
                out.total_secs,
                fmt_ppl(ppl["synth-wiki"])
            );
            rows.push((config.clone(), label.to_string(), ppl));
        }
    }

    // one table per dataset (T1 = wiki, T3 = ptb, T4 = c4)
    for (ds, paper) in [
        ("synth-wiki", "Table 1 (raw-WikiText2 analog)"),
        ("synth-ptb", "Table 3 (PTB analog)"),
        ("synth-c4-val", "Table 4 (C4-subset analog)"),
    ] {
        let mut header: Vec<&str> = vec!["method"];
        let cfg_list: Vec<String> = configs
            .iter()
            .filter(|c| rows.iter().any(|(rc, _, _)| rc == *c))
            .cloned()
            .collect();
        for c in &cfg_list {
            header.push(c);
        }
        let mut table = Table::new(paper, &header);
        let methods: Vec<String> = {
            let mut seen = Vec::new();
            for (_, m, _) in &rows {
                if !seen.contains(m) {
                    seen.push(m.clone());
                }
            }
            seen
        };
        for m in methods {
            let mut cells = vec![m.clone()];
            for c in &cfg_list {
                let v = rows
                    .iter()
                    .find(|(rc, rm, _)| rc == c && rm == &m)
                    .map(|(_, _, ppl)| fmt_ppl(ppl[ds]))
                    .unwrap_or_else(|| "-".into());
                cells.push(v);
            }
            table.row(cells);
        }
        finish(&ws, &table, &format!("table1_{}", ds.replace('-', "_")))?;
    }
    println!("Figure 2 is the sparsegpt rows of the tables above, read as series over model size.");
    Ok(())
}
