//! Figure 11: how close SparseGPT's partial-update approximation gets to
//! exact (per-row masked least-squares) reconstruction, layer by layer, at
//! 50% sparsity. The exact comparator is the O(d_row * d_col^3) solver the
//! paper's algorithm exists to avoid, so we run it on the `micro` config
//! with row subsampling and report the relative error ratio
//! (solver_error / exact_error - 1, the paper plots ~10-20%).

use anyhow::Result;
use sparsegpt::bench::{env_configs, env_usize, finish, prune_variant_opts};
use sparsegpt::coordinator::{PruneMethod, PruneOptions};
use sparsegpt::eval::report::Table;
use sparsegpt::harness::Workspace;
use sparsegpt::solver::sparsegpt_ref::Pattern;

fn main() -> Result<()> {
    let ws = Workspace::open()?;
    let config = env_configs(&["micro"]).remove(0);
    let rows = env_usize("SPARSEGPT_BENCH_EXACT_ROWS", 32);
    let dense = ws.load_model(&config)?;

    let out = prune_variant_opts(
        &ws,
        &dense,
        PruneOptions {
            method: PruneMethod::SparseGpt {
                pattern: Pattern::Unstructured(0.5),
                quant_bits: None,
            },
            exact_rows: Some(rows),
            ..Default::default()
        },
        sparsegpt::bench::calib_segments(),
        0,
    )?;

    let mut table = Table::new(
        &format!("Figure 11 (approximation quality, {config}, {rows} rows/matrix)"),
        &["layer", "matrix", "exact err", "sparsegpt err", "rel. excess"],
    );
    let mut ratios = Vec::new();
    for r in &out.reports {
        if let Some((exact, solver)) = r.exact_vs_solver {
            let excess = if exact > 0.0 { solver / exact - 1.0 } else { 0.0 };
            ratios.push(excess);
            table.row(vec![
                r.layer.to_string(),
                r.kind.label().to_string(),
                format!("{exact:.3e}"),
                format!("{solver:.3e}"),
                format!("{:+.1}%", excess * 100.0),
            ]);
        }
    }
    let mean = ratios.iter().sum::<f64>() / ratios.len().max(1) as f64;
    table.row(vec![
        "-".into(),
        "mean".into(),
        "-".into(),
        "-".into(),
        format!("{:+.1}%", mean * 100.0),
    ]);
    finish(&ws, &table, "fig11_approx_quality")
}
