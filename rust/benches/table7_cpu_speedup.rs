//! Table 7: CPU inference acceleration from unstructured sparsity
//! (the DeepSparse experiment). We run the full linear-layer stack of one
//! model (all blocks' q/k/v/out/fc1/fc2) over a 400-token batch — the
//! paper's OPT-2.7B setting — dense vs CSR (plus the row-permuted CSR
//! layout) at 40/50/60% sparsity, and report end-to-end speedups
//! (paper: 1.57x / 1.82x / 2.16x).
//!
//! Runtime depends only on shape and sparsity pattern, so the stack runs
//! on seed-0 random weights and needs no workspace, artifacts or data.
//! Kernels run on the process worker pool (sized from SPARSEGPT_THREADS;
//! the `workers` field in the JSON records the size actually used).
//!
//! Writes `BENCH_table7.json` (repo root + a copy under `reports/`):
//!   { "bench": "table7_cpu_speedup", "config": ..., "tokens": 400,
//!     "workers": ..., "rows": [
//!       { "layout": "csr", "sparsity": 0.5, "dense_secs": ...,
//!         "sparse_secs": ..., "speedup": ..., "ideal": 2.0 }, ...],
//!     "metrics": { ...Obs snapshot with per-worker busy_ns/tiles... } }
//!
//! Env knobs: SPARSEGPT_BENCH_CONFIGS (default "medium"),
//! SPARSEGPT_BENCH_TOKENS (400).

use std::collections::BTreeMap;
use std::path::PathBuf;

use anyhow::{anyhow, Result};
use sparsegpt::bench::{env_configs, env_usize};
use sparsegpt::eval::report::Table;
use sparsegpt::model::layout::PRUNABLE_KINDS;
use sparsegpt::model::ModelCfg;
use sparsegpt::obs::Obs;
use sparsegpt::solver::magnitude::magnitude_prune;
use sparsegpt::sparse::{dense_layer, CsrMatrix, WorkerPool};
use sparsegpt::tensor::Tensor;
use sparsegpt::util::json::Json;
use sparsegpt::util::prng::Rng;
use sparsegpt::util::timer::bench_fn;

fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect::<BTreeMap<_, _>>())
}

fn main() -> Result<()> {
    let config = env_configs(&["medium"]).remove(0);
    let cfg = ModelCfg::builtin(&config)
        .ok_or_else(|| anyhow!("unknown config {config:?} (expected nano..large)"))?;
    let tokens = env_usize("SPARSEGPT_BENCH_TOKENS", 400);
    let workers = WorkerPool::global().workers();
    // snapshot the shared pool's busy-time/tile counters into the BENCH doc
    let obs = Obs::default();
    obs.attach_pool(WorkerPool::global().clone());
    let mut rng = Rng::new(0);

    // one weight stack (all blocks, all linears) with random weights —
    // runtime depends only on shape/sparsity, not on trained values
    let shapes: Vec<(usize, usize)> = (0..cfg.layers)
        .flat_map(|_| PRUNABLE_KINDS.iter().map(|k| k.shape(&cfg)).collect::<Vec<_>>())
        .collect();
    let dense_ws: Vec<Tensor> = shapes
        .iter()
        .map(|(r, c)| Tensor::new(vec![*r, *c], (0..r * c).map(|_| rng.normal_f32()).collect()))
        .collect();
    let xs: Vec<Tensor> = shapes
        .iter()
        .map(|(_, c)| Tensor::new(vec![tokens, *c], (0..tokens * c).map(|_| rng.normal_f32()).collect()))
        .collect();

    println!("table7_cpu_speedup: {config}, {tokens} tokens, {workers} workers");
    let dense_stats = bench_fn(1, 3, || {
        for (w, x) in dense_ws.iter().zip(&xs) {
            std::hint::black_box(dense_layer(x, w));
        }
    });
    println!("dense stack: {:.3}s", dense_stats.median);

    let mut table = Table::new(
        &format!("Table 7 (CPU unstructured speedup, {config}, {tokens} tokens, {workers} workers)"),
        &["layout", "sparsity", "dense s", "sparse s", "speedup", "ideal"],
    );
    let mut rows = Vec::new();
    for p in [0.4, 0.5, 0.6] {
        let pruned: Vec<Tensor> = dense_ws.iter().map(|w| magnitude_prune(w, p).0).collect();
        for permuted in [false, true] {
            let layout = if permuted { "csr:perm" } else { "csr" };
            let csrs: Vec<CsrMatrix> = pruned
                .iter()
                .map(|w| {
                    if permuted {
                        CsrMatrix::from_dense_permuted(w)
                    } else {
                        CsrMatrix::from_dense(w)
                    }
                })
                .collect::<Result<_>>()?;
            let sparse_stats = bench_fn(1, 3, || {
                for (w, x) in csrs.iter().zip(&xs) {
                    std::hint::black_box(w.layer(x));
                }
            });
            let speedup = dense_stats.median / sparse_stats.median;
            println!(
                "p={p} {layout}: {:.3}s -> {:.3}s ({speedup:.2}x)",
                dense_stats.median, sparse_stats.median
            );
            table.row(vec![
                layout.to_string(),
                format!("{:.0}%", p * 100.0),
                format!("{:.3}", dense_stats.median),
                format!("{:.3}", sparse_stats.median),
                format!("{speedup:.2}x"),
                format!("{:.2}x", 1.0 / (1.0 - p)),
            ]);
            rows.push(obj(vec![
                ("layout", Json::Str(layout.to_string())),
                ("sparsity", Json::Num(p)),
                ("dense_secs", Json::Num(dense_stats.median)),
                ("sparse_secs", Json::Num(sparse_stats.median)),
                ("speedup", Json::Num(speedup)),
                ("ideal", Json::Num(1.0 / (1.0 - p))),
            ]));
        }
    }

    let report_dir = std::env::var_os("SPARSEGPT_REPORTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| "reports".into());
    std::fs::create_dir_all(&report_dir)?;
    print!("{}", table.render());
    table.save(&report_dir, "table7_cpu_speedup")?;
    let doc = obj(vec![
        ("bench", Json::Str("table7_cpu_speedup".into())),
        ("config", Json::Str(config.clone())),
        ("tokens", Json::Num(tokens as f64)),
        ("workers", Json::Num(workers as f64)),
        ("rows", Json::Arr(rows)),
        ("metrics", obs.snapshot().to_json()),
    ]);
    let text = doc.to_string_pretty();
    std::fs::write("BENCH_table7.json", &text)?;
    std::fs::write(report_dir.join("BENCH_table7.json"), &text)?;
    println!("(saved BENCH_table7.json + reports/table7_cpu_speedup.txt/.csv)");
    Ok(())
}
