//! Table 7: CPU inference acceleration from unstructured sparsity
//! (the DeepSparse experiment). We run the full linear-layer stack of one
//! model (all blocks' q/k/v/out/fc1/fc2) over a 400-token batch — the
//! paper's OPT-2.7B setting — dense vs CSR at 40/50/60% sparsity, and
//! report end-to-end speedups (paper: 1.57x / 1.82x / 2.16x).

use anyhow::Result;
use sparsegpt::bench::{env_configs, finish};
use sparsegpt::eval::report::Table;
use sparsegpt::harness::Workspace;
use sparsegpt::model::layout::PRUNABLE_KINDS;
use sparsegpt::solver::magnitude::magnitude_prune;
use sparsegpt::sparse::{dense_layer, CsrMatrix};
use sparsegpt::tensor::Tensor;
use sparsegpt::util::prng::Rng;
use sparsegpt::util::timer::bench_fn;

const TOKENS: usize = 400;

fn main() -> Result<()> {
    let ws = Workspace::open()?;
    let config = env_configs(&["medium"]).remove(0);
    let cfg = ws.config(&config)?;
    let mut rng = Rng::new(0);

    // one weight stack (all blocks, all linears) with random weights —
    // runtime depends only on shape/sparsity, not on trained values
    let shapes: Vec<(usize, usize)> = (0..cfg.layers)
        .flat_map(|_| PRUNABLE_KINDS.iter().map(|k| k.shape(&cfg)).collect::<Vec<_>>())
        .collect();
    let dense_ws: Vec<Tensor> = shapes
        .iter()
        .map(|(r, c)| Tensor::new(vec![*r, *c], (0..r * c).map(|_| rng.normal_f32()).collect()))
        .collect();
    let xs: Vec<Tensor> = shapes
        .iter()
        .map(|(_, c)| Tensor::new(vec![TOKENS, *c], (0..TOKENS * c).map(|_| rng.normal_f32()).collect()))
        .collect();

    let dense_stats = bench_fn(1, 3, || {
        for (w, x) in dense_ws.iter().zip(&xs) {
            std::hint::black_box(dense_layer(x, w));
        }
    });
    println!("dense stack: {:.3}s", dense_stats.median);

    let mut table = Table::new(
        &format!("Table 7 (CPU unstructured speedup, {config}, {TOKENS} tokens)"),
        &["sparsity", "dense s", "sparse s", "speedup", "ideal"],
    );
    for p in [0.4, 0.5, 0.6] {
        let csrs: Vec<CsrMatrix> = dense_ws
            .iter()
            .map(|w| CsrMatrix::from_dense(&magnitude_prune(w, p).0))
            .collect();
        let sparse_stats = bench_fn(1, 3, || {
            for (w, x) in csrs.iter().zip(&xs) {
                std::hint::black_box(w.layer(x));
            }
        });
        let speedup = dense_stats.median / sparse_stats.median;
        println!("p={p}: {:.3}s -> {:.3}s ({speedup:.2}x)", dense_stats.median, sparse_stats.median);
        table.row(vec![
            format!("{:.0}%", p * 100.0),
            format!("{:.3}", dense_stats.median),
            format!("{:.3}", sparse_stats.median),
            format!("{speedup:.2}x"),
            format!("{:.2}x", 1.0 / (1.0 - p)),
        ]);
    }
    finish(&ws, &table, "table7_cpu_speedup")
}
