//! Figure 7 + Tables 5/6: partial 2:4 sensitivity. Which 2/3 of the model
//! should be 2:4-sparsified (skip one layer type vs one depth third), and
//! the prefix-fraction sequence 1/2, 2/3, 3/4, 4/5, full that a single
//! sequential SparseGPT pass can produce.

use anyhow::Result;
use sparsegpt::bench::{env_configs, eval_all, finish, prune_variant_opts};
use sparsegpt::coordinator::{PruneMethod, PruneOptions, SkipSpec};
use sparsegpt::eval::report::{fmt_ppl, Table};
use sparsegpt::harness::Workspace;
use sparsegpt::solver::sparsegpt_ref::Pattern;

fn main() -> Result<()> {
    let ws = Workspace::open()?;
    let config = env_configs(&["small"]).remove(0);
    let dense = ws.load_model(&config)?;
    let calib = sparsegpt::bench::calib_segments();
    let method = PruneMethod::SparseGpt { pattern: Pattern::NM(2, 4), quant_bits: None };

    // --- Figure 7: skip one layer type or one third ---
    let mut t7 = Table::new(
        &format!("Figure 7 (partial 2:4 sensitivity, {config})"),
        &["skip", "sparsity", "wiki", "ptb", "c4"],
    );
    let skips = [
        SkipSpec::LayerType("attn".into()),
        SkipSpec::LayerType("fc1".into()),
        SkipSpec::LayerType("fc2".into()),
        SkipSpec::Third(0),
        SkipSpec::Third(1),
        SkipSpec::Third(2),
    ];
    for skip in skips {
        let label = skip.label();
        let out = prune_variant_opts(
            &ws,
            &dense,
            PruneOptions { method: method.clone(), skip, ..Default::default() },
            calib,
            0,
        )?;
        let ppl = eval_all(&ws, &out.params)?;
        println!("{label}: wiki {}", fmt_ppl(ppl["synth-wiki"]));
        t7.row(vec![
            label,
            format!("{:.3}", out.overall_sparsity()),
            fmt_ppl(ppl["synth-wiki"]),
            fmt_ppl(ppl["synth-ptb"]),
            fmt_ppl(ppl["synth-c4-val"]),
        ]);
    }
    finish(&ws, &t7, "fig7_partial_24")?;

    // --- Tables 5/6: prefix fractions ---
    let mut t5 = Table::new(
        &format!("Table 5/6 (prefix 2:4, {config})"),
        &["fraction", "wiki", "ptb", "c4"],
    );
    let dense_ppl = eval_all(&ws, &dense)?;
    t5.row(vec![
        "dense".into(),
        fmt_ppl(dense_ppl["synth-wiki"]),
        fmt_ppl(dense_ppl["synth-ptb"]),
        fmt_ppl(dense_ppl["synth-c4-val"]),
    ]);
    for frac in [0.5, 2.0 / 3.0, 1.0] {
        let out = prune_variant_opts(
            &ws,
            &dense,
            PruneOptions {
                method: method.clone(),
                skip: SkipSpec::PrefixFraction(frac),
                ..Default::default()
            },
            calib,
            0,
        )?;
        let ppl = eval_all(&ws, &out.params)?;
        println!("prefix {frac:.2}: wiki {}", fmt_ppl(ppl["synth-wiki"]));
        t5.row(vec![
            format!("{frac:.2}"),
            fmt_ppl(ppl["synth-wiki"]),
            fmt_ppl(ppl["synth-ptb"]),
            fmt_ppl(ppl["synth-c4-val"]),
        ]);
    }
    finish(&ws, &t5, "table5_6_prefix_24")
}
