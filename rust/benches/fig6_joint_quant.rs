//! Figure 6 (+ App. C "50% + 3-bit"): joint sparsification + quantization
//! vs size-equivalent pure quantization across the family. The GPTQ
//! baseline is the same artifact with sparsity 0 — the paper's observation
//! that both algorithms share the column-greedy framework. One `Sweep` job
//! per config (shared calibration across all six compressed variants).

use anyhow::Result;
use sparsegpt::api::{HumanSink, JobSpec, PruneSpec, Session, SweepReport, SweepSpec};
use sparsegpt::bench::{calib_segments, env_configs, eval_segments, finish};
use sparsegpt::eval::report::{fmt_ppl, Table};
use sparsegpt::solver::quant::effective_bits;

fn main() -> Result<()> {
    let mut session = Session::new();
    let configs = env_configs(&["small", "medium"]);

    let variants: Vec<(&str, f64, PruneSpec)> = vec![
        (
            "sparsegpt 50%+4bit",
            effective_bits(0.5, 4.0),
            PruneSpec::sparsegpt(0.5).with_quant_bits(4),
        ),
        ("gptq 3bit", 3.0, PruneSpec::sparsegpt(0.0).with_quant_bits(3)),
        (
            "sparsegpt 50%+3bit",
            effective_bits(0.5, 3.0),
            PruneSpec::sparsegpt(0.5).with_quant_bits(3),
        ),
        ("gptq 2.5bit(rtn grid)", 2.5, PruneSpec::sparsegpt(0.0).with_quant_bits(2)),
        (
            "sparsegpt 2:4+4bit",
            effective_bits(0.5, 4.0),
            PruneSpec::sparsegpt_nm(2, 4).with_quant_bits(4),
        ),
        (
            "sparsegpt 4:8+4bit",
            effective_bits(0.5, 4.0),
            PruneSpec::sparsegpt_nm(4, 8).with_quant_bits(4),
        ),
    ];

    // one sweep per config; missing models produce "-" columns
    let mut reports: Vec<Option<SweepReport>> = Vec::new();
    for config in &configs {
        let spec = SweepSpec::new(config)
            .dense(true)
            .dataset("synth-wiki")
            .calib(calib_segments())
            .max_segments(eval_segments())
            .variants(variants.iter().map(|(_, _, v)| v.clone()).collect());
        match session.run(&JobSpec::Sweep(spec), &mut HumanSink::new()) {
            Ok(r) => reports.push(r.into_sweep()),
            Err(e) => {
                eprintln!("skipping {config}: {e:#}");
                reports.push(None);
            }
        }
    }

    let mut header = vec!["variant".to_string(), "bits/w".to_string()];
    header.extend(configs.iter().cloned());
    let hdr: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new("Figure 6 (synth-wiki ppl)", &hdr);

    let cell = |r: &Option<SweepReport>, pick: &dyn Fn(&SweepReport) -> Option<f64>| match r {
        Some(rep) => pick(rep).map(fmt_ppl).unwrap_or_else(|| "-".into()),
        None => "-".into(),
    };
    let mut dense_row = vec!["dense fp32".to_string(), "32.0".to_string()];
    for r in &reports {
        dense_row.push(cell(r, &|rep| {
            rep.dense.as_ref().and_then(|d| d.ppl.get("synth-wiki").copied())
        }));
    }
    table.row(dense_row);
    for (vi, (label, bits, _)) in variants.iter().enumerate() {
        let mut cells = vec![label.to_string(), format!("{bits:.1}")];
        for r in &reports {
            cells.push(cell(r, &|rep| {
                rep.variants.get(vi).and_then(|v| v.ppl.get("synth-wiki").copied())
            }));
        }
        table.row(cells);
    }
    finish(session.workspace()?, &table, "fig6_joint_quant")
}
