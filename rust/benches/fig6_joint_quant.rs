//! Figure 6 (+ App. C "50% + 3-bit"): joint sparsification + quantization
//! vs size-equivalent pure quantization across the family. The GPTQ
//! baseline is the same artifact with sparsity 0 — the paper's observation
//! that both algorithms share the column-greedy framework.

use anyhow::Result;
use sparsegpt::bench::{env_configs, eval_one, finish, prune_variant};
use sparsegpt::coordinator::PruneMethod;
use sparsegpt::eval::report::{fmt_ppl, Table};
use sparsegpt::harness::Workspace;
use sparsegpt::solver::quant::effective_bits;
use sparsegpt::solver::sparsegpt_ref::Pattern;

fn main() -> Result<()> {
    let ws = Workspace::open()?;
    let configs = env_configs(&["small", "medium"]);

    let mut header = vec!["variant".to_string(), "bits/w".to_string()];
    header.extend(configs.iter().cloned());
    let hdr: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new("Figure 6 (synth-wiki ppl)", &hdr);

    let variants: Vec<(&str, f64, Option<PruneMethod>)> = vec![
        ("dense fp32", 32.0, None),
        (
            "sparsegpt 50%+4bit",
            effective_bits(0.5, 4.0),
            Some(PruneMethod::SparseGpt { pattern: Pattern::Unstructured(0.5), quant_bits: Some(4) }),
        ),
        (
            "gptq 3bit",
            3.0,
            Some(PruneMethod::SparseGpt { pattern: Pattern::Unstructured(0.0), quant_bits: Some(3) }),
        ),
        (
            "sparsegpt 50%+3bit",
            effective_bits(0.5, 3.0),
            Some(PruneMethod::SparseGpt { pattern: Pattern::Unstructured(0.5), quant_bits: Some(3) }),
        ),
        (
            "gptq 2.5bit(rtn grid)",
            2.5,
            Some(PruneMethod::SparseGpt { pattern: Pattern::Unstructured(0.0), quant_bits: Some(2) }),
        ),
        (
            "sparsegpt 2:4+4bit",
            effective_bits(0.5, 4.0),
            Some(PruneMethod::SparseGpt { pattern: Pattern::NM(2, 4), quant_bits: Some(4) }),
        ),
        (
            "sparsegpt 4:8+4bit",
            effective_bits(0.5, 4.0),
            Some(PruneMethod::SparseGpt { pattern: Pattern::NM(4, 8), quant_bits: Some(4) }),
        ),
    ];

    for (label, bits, method) in variants {
        let mut cells = vec![label.to_string(), format!("{bits:.1}")];
        for config in &configs {
            let dense = match ws.load_model(config) {
                Ok(p) => p,
                Err(_) => {
                    cells.push("-".into());
                    continue;
                }
            };
            let ppl = match &method {
                None => eval_one(&ws, &dense, "synth-wiki")?,
                Some(m) => {
                    let out = prune_variant(&ws, &dense, m.clone())?;
                    eval_one(&ws, &out.params, "synth-wiki")?
                }
            };
            println!("{label} / {config}: {}", fmt_ppl(ppl));
            cells.push(fmt_ppl(ppl));
        }
        table.row(cells);
    }
    finish(&ws, &table, "fig6_joint_quant")
}
