//! Table 2: zero-shot accuracy of the largest routinely-trained model,
//! dense vs magnitude-50% vs SparseGPT-{50%, 4:8, 2:4}, over the five
//! synthetic tasks (Lambada/PIQA/ARC-e/ARC-c/StoryCloze analogs). One
//! `Sweep` job with the perplexity pass disabled — only the zero-shot
//! suite runs on each variant.

use anyhow::Result;
use sparsegpt::api::{HumanSink, JobSpec, PruneSpec, Session, SweepSpec};
use sparsegpt::bench::{calib_segments, env_configs, env_usize, finish};
use sparsegpt::eval::report::Table;
use sparsegpt::eval::zeroshot::ZeroShotTask;

fn main() -> Result<()> {
    let mut session = Session::new();
    let config = env_configs(&["medium"]).remove(0);
    let n_items = env_usize("SPARSEGPT_BENCH_ITEMS", 100);

    let spec = SweepSpec::new(&config)
        .dense(true)
        .calib(calib_segments())
        .max_segments(0) // no perplexity pass, zero-shot only
        .zeroshot(n_items)
        .variants(vec![
            PruneSpec::magnitude(0.5),
            PruneSpec::sparsegpt(0.5),
            PruneSpec::sparsegpt_nm(4, 8),
            PruneSpec::sparsegpt_nm(2, 4),
        ]);
    let report = session
        .run(&JobSpec::Sweep(spec), &mut HumanSink::new())?
        .into_sweep()
        .expect("sweep job returns a sweep report");

    let mut header = vec!["method".to_string(), "spars.".to_string()];
    for t in ZeroShotTask::ALL {
        header.push(t.name().to_string());
    }
    header.push("avg".to_string());
    let hdr: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(&format!("Table 2 (zero-shot, {config})"), &hdr);

    for v in report.all_rows() {
        let mut cells = vec![v.label.clone(), format!("{:.2}", v.sparsity)];
        match &v.zeroshot {
            Some(zs) => {
                for (_, acc) in &zs.rows {
                    cells.push(format!("{:.1}", acc * 100.0));
                }
                cells.push(format!("{:.1}", zs.avg * 100.0));
            }
            // SPARSEGPT_BENCH_ITEMS=0 disables the zero-shot pass
            None => cells.extend(std::iter::repeat("-".to_string()).take(6)),
        }
        table.row(cells);
    }
    finish(session.workspace()?, &table, "table2_zeroshot")
}
