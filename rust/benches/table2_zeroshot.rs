//! Table 2: zero-shot accuracy of the largest routinely-trained model,
//! dense vs magnitude-50% vs SparseGPT-{50%, 4:8, 2:4}, over the five
//! synthetic tasks (Lambada/PIQA/ARC-e/ARC-c/StoryCloze analogs).

use anyhow::Result;
use sparsegpt::bench::{env_configs, env_usize, finish, prune_variant};
use sparsegpt::coordinator::PruneMethod;
use sparsegpt::data::corpus::Lexicon;
use sparsegpt::eval::report::Table;
use sparsegpt::eval::zeroshot::{gen_items, zero_shot_accuracy, ZeroShotTask};
use sparsegpt::harness::Workspace;
use sparsegpt::solver::sparsegpt_ref::Pattern;

fn main() -> Result<()> {
    let ws = Workspace::open()?;
    let config = env_configs(&["medium"]).remove(0);
    let n_items = env_usize("SPARSEGPT_BENCH_ITEMS", 100);
    let dense = ws.load_model(&config)?;
    let tok = ws.tokenizer()?;
    let lex = Lexicon::new(0);

    let mut header = vec!["method".to_string(), "spars.".to_string()];
    for t in ZeroShotTask::ALL {
        header.push(t.name().to_string());
    }
    header.push("avg".to_string());
    let hdr: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(&format!("Table 2 (zero-shot, {config})"), &hdr);

    let variants: Vec<(String, Option<PruneMethod>)> = vec![
        ("dense".into(), None),
        (
            "magnitude-50%".into(),
            Some(PruneMethod::Magnitude { pattern: Pattern::Unstructured(0.5) }),
        ),
        (
            "sparsegpt-50%".into(),
            Some(PruneMethod::SparseGpt { pattern: Pattern::Unstructured(0.5), quant_bits: None }),
        ),
        (
            "sparsegpt-4:8".into(),
            Some(PruneMethod::SparseGpt { pattern: Pattern::NM(4, 8), quant_bits: None }),
        ),
        (
            "sparsegpt-2:4".into(),
            Some(PruneMethod::SparseGpt { pattern: Pattern::NM(2, 4), quant_bits: None }),
        ),
    ];

    for (label, method) in variants {
        let (params, sparsity) = match method {
            None => (dense.clone(), 0.0),
            Some(m) => {
                let out = prune_variant(&ws, &dense, m)?;
                let s = out.overall_sparsity();
                (out.params, s)
            }
        };
        let mut cells = vec![label.clone(), format!("{sparsity:.2}")];
        let mut sum = 0.0;
        for task in ZeroShotTask::ALL {
            let items = gen_items(task, &lex, 7, n_items);
            let acc = zero_shot_accuracy(&ws.rt, &params, &tok, &items)?;
            sum += acc;
            cells.push(format!("{:.1}", acc * 100.0));
        }
        cells.push(format!("{:.1}", sum / ZeroShotTask::ALL.len() as f64 * 100.0));
        println!("{label}: done");
        table.row(cells);
    }
    finish(&ws, &table, "table2_zeroshot")
}
