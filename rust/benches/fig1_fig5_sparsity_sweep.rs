//! Figures 1 and 5: perplexity vs uniform sparsity (10%..80%) for SparseGPT
//! vs magnitude pruning, on the two largest trained configs (the OPT-175B /
//! BLOOM-176B stand-ins). One `Sweep` job per config; calibration is drawn
//! once and shared by all 16 variants.

use anyhow::Result;
use sparsegpt::api::{HumanSink, JobSpec, PruneSpec, Session, SweepSpec};
use sparsegpt::bench::{calib_segments, env_configs, eval_segments, finish};
use sparsegpt::eval::report::{fmt_ppl, Table};

fn main() -> Result<()> {
    let mut session = Session::new();
    let configs = env_configs(&["medium", "small"]);
    let points: Vec<f64> = match std::env::var("SPARSEGPT_BENCH_POINTS") {
        Ok(v) => v.split(',').filter_map(|s| s.trim().parse().ok()).collect(),
        _ => vec![0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8],
    };

    for (i, config) in configs.iter().enumerate() {
        let mut spec = SweepSpec::new(config)
            .dense(true)
            .dataset("synth-wiki")
            .calib(calib_segments())
            .max_segments(eval_segments());
        for &p in &points {
            spec = spec.variant(PruneSpec::sparsegpt(p)).variant(PruneSpec::magnitude(p));
        }
        let report = match session.run(&JobSpec::Sweep(spec), &mut HumanSink::new()) {
            Ok(r) => r.into_sweep().expect("sweep job returns a sweep report"),
            Err(e) => {
                eprintln!("skipping {config}: {e:#}");
                continue;
            }
        };
        let dense_ppl = report
            .dense
            .as_ref()
            .and_then(|d| d.ppl.get("synth-wiki").copied())
            .unwrap_or(f64::NAN);
        let fig = if i == 0 { "Figure 1" } else { "Figure 5" };
        let mut table = Table::new(
            &format!("{fig} ({config}, synth-wiki, dense {})", fmt_ppl(dense_ppl)),
            &["sparsity", "sparsegpt", "magnitude"],
        );
        for (j, &p) in points.iter().enumerate() {
            let s = &report.variants[2 * j];
            let m = &report.variants[2 * j + 1];
            table.row(vec![
                format!("{:.0}%", p * 100.0),
                fmt_ppl(s.ppl["synth-wiki"]),
                fmt_ppl(m.ppl["synth-wiki"]),
            ]);
        }
        finish(session.workspace()?, &table, &format!("fig1_fig5_{config}"))?;
    }
    Ok(())
}
