//! Figures 1 and 5: perplexity vs uniform sparsity (10%..80%) for SparseGPT
//! vs magnitude pruning, on the two largest trained configs (the OPT-175B /
//! BLOOM-176B stand-ins).

use anyhow::Result;
use sparsegpt::bench::{env_configs, eval_one, finish, prune_variant};
use sparsegpt::coordinator::PruneMethod;
use sparsegpt::eval::report::{fmt_ppl, Table};
use sparsegpt::harness::Workspace;
use sparsegpt::solver::sparsegpt_ref::Pattern;

fn main() -> Result<()> {
    let ws = Workspace::open()?;
    let configs = env_configs(&["medium", "small"]);
    let points: Vec<f64> = match std::env::var("SPARSEGPT_BENCH_POINTS") {
        Ok(v) => v.split(',').filter_map(|s| s.trim().parse().ok()).collect(),
        _ => vec![0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8],
    };

    for (i, config) in configs.iter().enumerate() {
        let dense = match ws.load_model(config) {
            Ok(p) => p,
            Err(e) => {
                eprintln!("skipping {config}: {e:#}");
                continue;
            }
        };
        let dense_ppl = eval_one(&ws, &dense, "synth-wiki")?;
        let fig = if i == 0 { "Figure 1" } else { "Figure 5" };
        let mut table = Table::new(
            &format!("{fig} ({config}, synth-wiki, dense {})", fmt_ppl(dense_ppl)),
            &["sparsity", "sparsegpt", "magnitude"],
        );
        for &p in &points {
            let s = prune_variant(
                &ws,
                &dense,
                PruneMethod::SparseGpt { pattern: Pattern::Unstructured(p), quant_bits: None },
            )?;
            let m = prune_variant(
                &ws,
                &dense,
                PruneMethod::Magnitude { pattern: Pattern::Unstructured(p) },
            )?;
            let ps = eval_one(&ws, &s.params, "synth-wiki")?;
            let pm = eval_one(&ws, &m.params, "synth-wiki")?;
            println!("{config} p={p:.1}: sparsegpt {} magnitude {}", fmt_ppl(ps), fmt_ppl(pm));
            table.row(vec![format!("{:.0}%", p * 100.0), fmt_ppl(ps), fmt_ppl(pm)]);
        }
        finish(&ws, &table, &format!("fig1_fig5_{config}"))?;
    }
    Ok(())
}
