//! Serve throughput: end-to-end tokens/sec of the continuous-batching
//! decode engine — dense vs CSR (50% / 60% unstructured) vs 2:4 packed,
//! f32 vs quantized (q8/q4 codes dequantized inside the kernels), each in
//! both decode modes: **KV-cached incremental decode** (per-token cost
//! O(layers)) vs the **uncached full re-forward** reference path
//! (per-token cost O(ctx · layers)). The serving-side counterpart of
//! Table 7/8's kernel-level speedups plus the Fig.-6 size trade-off made
//! measurable on the serving path: every row reports `effective_bits` /
//! `bytes_per_weight` (50% sparse + 4-bit + bitmask = 3.0 bits). Runtime
//! depends only on shape + sparsity pattern, so the workload runs on
//! seed-0 random weights and needs no artifacts, data or checkpoints.
//!
//! The default prompt length is 256 — past the 128-token attention window,
//! so the cached rows also pay ring eviction — and the cached/uncached
//! ratio ("vs uncached") is the headline: cached decode must win whenever
//! contexts reach seq and beyond. Throughput here is *end-to-end*:
//! tokens / (decode_secs + prefill_secs), so the cached mode is charged
//! for its prefill pass (which produces the first token) and the numbers
//! stay comparable to the uncached mode, which pays for prompt processing
//! inside every re-forward decode step.
//!
//! Writes `BENCH_serve.json` (repo root + a copy under `reports/`) so the
//! bench trajectory is machine-readable (`workers` records the kernel
//! worker-pool size the engines decoded on):
//!   { "bench": "serve_throughput", "config": ..., "workers": ..., "rows": [
//!       { "variant": "csr-60%", "kv": "cached", "density": ...,
//!         "effective_bits": ..., "bytes_per_weight": ...,
//!         "tokens": ..., "decode_secs": ..., "prefill_secs": ...,
//!         "tokens_per_sec": ..., "speedup_vs_dense": ...,
//!         "speedup_vs_uncached": ... }, ...],
//!     "metrics": { ...final Obs snapshot across every measured engine... } }
//! plus one `"variant": "fleet-3"` row ("models": 3): a single engine
//! serving the dense default with csr-50% and q4-50% as named mmap-backed
//! fleet variants, requests round-robined across them with per-request
//! `model=` routing, and `"variant": "replicas-{1,2,4}"` rows: the cached
//! csr-50% engine behind the admission router at 1/2/4 replicas (each fed
//! a full batch; wall-clock is the slowest replica, so tokens/sec shows
//! scale-out).
//!
//! Env knobs: SPARSEGPT_BENCH_CONFIGS (default "small"),
//! SPARSEGPT_BENCH_SERVE_REQUESTS (4), SPARSEGPT_BENCH_SERVE_TOKENS (4),
//! SPARSEGPT_BENCH_SERVE_PROMPT (256).

use std::collections::BTreeMap;
use std::path::PathBuf;

use anyhow::{anyhow, Result};
use sparsegpt::bench::{env_configs, env_usize};
use sparsegpt::eval::report::Table;
use sparsegpt::model::init::init_params;
use sparsegpt::model::layout::{FlatParams, PRUNABLE_KINDS};
use sparsegpt::model::ModelCfg;
use sparsegpt::obs::Obs;
use sparsegpt::model::sparse_store::SparseStore;
use sparsegpt::serve::{
    EngineOptions, ModelFleet, Router, SchedulerPolicy, ServeEngine, ServeRequest, SparseModel,
};
use sparsegpt::solver::magnitude::{magnitude_prune, magnitude_prune_nm};
use sparsegpt::sparse::{PackFormat, PackPolicy, WorkerPool};
use sparsegpt::tensor::Tensor;
use sparsegpt::util::json::Json;
use sparsegpt::util::prng::Rng;

fn prune_all(dense: &FlatParams, f: impl Fn(&Tensor) -> Tensor) -> FlatParams {
    let mut fp = dense.clone();
    for layer in 0..fp.cfg.layers {
        for kind in PRUNABLE_KINDS {
            let w = fp.get_linear(kind, layer).unwrap();
            fp.set_linear(kind, layer, &f(&w)).unwrap();
        }
    }
    fp
}

fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect::<BTreeMap<_, _>>())
}

fn main() -> Result<()> {
    let config = env_configs(&["small"]).remove(0);
    let cfg = ModelCfg::builtin(&config)
        .ok_or_else(|| anyhow!("unknown config {config:?} (expected nano..large)"))?;
    let requests = env_usize("SPARSEGPT_BENCH_SERVE_REQUESTS", 4);
    let tokens = env_usize("SPARSEGPT_BENCH_SERVE_TOKENS", 4);
    let prompt_len = env_usize("SPARSEGPT_BENCH_SERVE_PROMPT", 256);
    let dense = init_params(&cfg, 0);

    // one shared synthetic workload: full batch from step 0, greedy
    // sampling, so every variant and mode decodes an identical schedule
    let workload = |n_req: usize, n_tok: usize| -> Vec<(usize, ServeRequest)> {
        let mut rng = Rng::new(7);
        (0..n_req)
            .map(|i| {
                let prompt: Vec<i32> =
                    (0..prompt_len).map(|_| rng.below(cfg.vocab) as i32).collect();
                (0, ServeRequest {
                    id: i as u64,
                    prompt,
                    max_new_tokens: n_tok,
                    seed: i as u64,
                    model: None,
                })
            })
            .collect()
    };
    let batch = requests.max(1);
    let opts_for = |kv_cache: bool| EngineOptions {
        policy: SchedulerPolicy {
            max_batch: batch,
            max_wait: 0,
            queue_cap: batch,
            ..SchedulerPolicy::default()
        },
        temperature: 0.0,
        top_k: 0,
        kv_cache,
        ..EngineOptions::default()
    };

    let w50 = prune_all(&dense, |w| magnitude_prune(w, 0.5).0);
    let w60 = prune_all(&dense, |w| magnitude_prune(w, 0.6).0);
    let wnm = prune_all(&dense, |w| magnitude_prune_nm(w, 2, 4).0);
    let variants: Vec<(&str, FlatParams, PackFormat)> = vec![
        ("dense", dense.clone(), PackFormat::Dense),
        ("csr-50%", w50.clone(), PackFormat::Csr),
        ("csr-60%", w60, PackFormat::Csr),
        ("nm-2:4", wnm.clone(), PackFormat::Nm(2, 4)),
        // quantized legs: f32 vs q8 vs q4 at 50% / 2:4 sparsity — the
        // Fig.-6 size/speed trade-off on the serving path
        ("q8-50%", w50.clone(), PackFormat::QCsr { bits: 8, group: 0 }),
        ("q4-50%", w50, PackFormat::QCsr { bits: 4, group: 0 }),
        ("q8-2:4", wnm.clone(), PackFormat::QNm { bits: 8, group: 0 }),
        ("q4-2:4", wnm, PackFormat::QNm { bits: 4, group: 0 }),
    ];

    println!(
        "serve_throughput: {config}, {requests} requests x {tokens} tokens, \
         prompt {prompt_len}, batch {batch}"
    );
    let mut table = Table::new(
        &format!(
            "serve throughput ({config}, {requests} req x {tokens} tok, prompt {prompt_len})"
        ),
        &[
            "variant",
            "kv",
            "density",
            "bits/w",
            "tokens",
            "total s",
            "tok/s",
            "vs dense",
            "vs uncached",
        ],
    );
    let mut rows = Vec::new();
    // one registry across every measured engine: the BENCH doc embeds its
    // final snapshot so a bench run's token/step/phase totals ride along
    let obs = Obs::default();
    // dense baseline tokens/sec per mode, for the per-mode "vs dense" column
    let mut dense_tps = [0.0f64; 2];
    for (label, params, fmt) in &variants {
        let model = SparseModel::from_params(params, &PackPolicy::with_format(*fmt))?;
        let mut mode_tps = [0.0f64; 2];
        for (mi, kv_cache) in [false, true].into_iter().enumerate() {
            let opts = opts_for(kv_cache);
            // warmup step keeps first-touch allocation out of the timing
            let _ = ServeEngine::new(&model, opts).run(workload(1, 1), &mut |_| {})?;
            let out = ServeEngine::new(&model, opts)
                .with_obs(obs.clone())
                .run(workload(batch, tokens), &mut |_| {})?;
            // end-to-end throughput: charge the cached mode its prefill
            // pass (which yields each request's first token)
            let total_secs = out.decode_secs + out.prefill_secs;
            let tps = if total_secs > 0.0 { out.tokens as f64 / total_secs } else { 0.0 };
            mode_tps[mi] = tps;
            if *label == "dense" {
                dense_tps[mi] = tps;
            }
            let vs_dense = if dense_tps[mi] > 0.0 { tps / dense_tps[mi] } else { 1.0 };
            let vs_uncached = if kv_cache && mode_tps[0] > 0.0 { tps / mode_tps[0] } else { 1.0 };
            let kv = if kv_cache { "cached" } else { "uncached" };
            println!(
                "  {label:<8} {kv:<8} density {:.3}  {} tok in {:.3}s -> {tps:.1} tok/s \
                 ({vs_dense:.2}x dense, {vs_uncached:.2}x uncached)",
                model.density(),
                out.tokens,
                total_secs
            );
            table.row(vec![
                label.to_string(),
                kv.to_string(),
                format!("{:.3}", model.density()),
                format!("{:.2}", model.effective_bits()),
                out.tokens.to_string(),
                format!("{:.3}", total_secs),
                format!("{tps:.1}"),
                format!("{vs_dense:.2}x"),
                format!("{vs_uncached:.2}x"),
            ]);
            rows.push(obj(vec![
                ("variant", Json::Str(label.to_string())),
                ("kv", Json::Str(kv.to_string())),
                ("density", Json::Num(model.density())),
                ("effective_bits", Json::Num(model.effective_bits())),
                ("bytes_per_weight", Json::Num(model.effective_bits() / 8.0)),
                ("tokens", Json::Num(out.tokens as f64)),
                ("decode_secs", Json::Num(out.decode_secs)),
                ("prefill_secs", Json::Num(out.prefill_secs)),
                ("tokens_per_sec", Json::Num(tps)),
                ("speedup_vs_dense", Json::Num(vs_dense)),
                ("speedup_vs_uncached", Json::Num(vs_uncached)),
            ]));
        }
    }

    // fleet row: one process serving a 3-model fleet (dense default plus
    // csr-50% and q4-50% as named mmap-backed variants) with per-request
    // model= routing — the multi-tenant overhead against the single-model
    // cached rows above
    {
        let fleet_dir =
            std::env::temp_dir().join(format!("sgpt_bench_fleet_{}", std::process::id()));
        std::fs::create_dir_all(&fleet_dir)?;
        let mut named = Vec::new();
        for (name, idx) in [("csr-50%", 1usize), ("q4-50%", 5)] {
            let (_, params, fmt) = &variants[idx];
            let store = SparseStore::pack(params, &PackPolicy::with_format(*fmt), name)?;
            let path = fleet_dir.join(format!("{}.spkt", name.replace(['%', ':'], "")));
            store.save(&path)?;
            named.push((name.to_string(), path));
        }
        let default_model =
            SparseModel::from_params(&variants[0].1, &PackPolicy::with_format(PackFormat::Dense))?;
        let routes = [None, Some("csr-50%".to_string()), Some("q4-50%".to_string())];
        let fleet_workload: Vec<(usize, ServeRequest)> = workload(batch, tokens)
            .into_iter()
            .enumerate()
            .map(|(i, (step, mut req))| {
                req.model = routes[i % routes.len()].clone();
                (step, req)
            })
            .collect();
        let fleet = ModelFleet::new(&cfg, &named, 0)?;
        let out = ServeEngine::new(&default_model, opts_for(true))
            .with_fleet(fleet)
            .with_obs(obs.clone())
            .run(fleet_workload, &mut |_| {})?;
        let total_secs = out.decode_secs + out.prefill_secs;
        let tps = if total_secs > 0.0 { out.tokens as f64 / total_secs } else { 0.0 };
        let vs_dense = if dense_tps[1] > 0.0 { tps / dense_tps[1] } else { 1.0 };
        println!(
            "  {:<8} {:<8} 3 models  {} tok in {total_secs:.3}s -> {tps:.1} tok/s \
             ({vs_dense:.2}x dense-cached)",
            "fleet-3", "cached", out.tokens
        );
        table.row(vec![
            "fleet-3".to_string(),
            "cached".to_string(),
            format!("{:.3}", default_model.density()),
            format!("{:.2}", default_model.effective_bits()),
            out.tokens.to_string(),
            format!("{total_secs:.3}"),
            format!("{tps:.1}"),
            format!("{vs_dense:.2}x"),
            "-".to_string(),
        ]);
        rows.push(obj(vec![
            ("variant", Json::Str("fleet-3".into())),
            ("kv", Json::Str("cached".into())),
            ("models", Json::Num(3.0)),
            ("density", Json::Num(default_model.density())),
            ("effective_bits", Json::Num(default_model.effective_bits())),
            ("bytes_per_weight", Json::Num(default_model.effective_bits() / 8.0)),
            ("tokens", Json::Num(out.tokens as f64)),
            ("decode_secs", Json::Num(out.decode_secs)),
            ("prefill_secs", Json::Num(out.prefill_secs)),
            ("tokens_per_sec", Json::Num(tps)),
            ("speedup_vs_dense", Json::Num(vs_dense)),
            ("speedup_vs_uncached", Json::Num(1.0)),
        ]));
        std::fs::remove_dir_all(&fleet_dir).ok();
    }

    // scale-out rows: the cached csr-50% engine behind the admission
    // router at 1/2/4 replicas. Every replica is fed one full batch, so
    // the workload grows with the fleet; aggregate wall-clock is the
    // slowest replica's and tokens/sec is the scale-out headline
    {
        let (_, params, fmt) = &variants[1];
        let model = SparseModel::from_params(params, &PackPolicy::with_format(*fmt))?;
        let mut single_tps = 0.0f64;
        for n in [1usize, 2, 4] {
            let router = Router::new(&model, opts_for(true), n).with_obs(obs.clone());
            // warmup keeps replica-thread spinup and first-touch
            // allocation out of the timing
            let _ = router.run(workload(n, 1), &mut |_| {})?;
            let out = router.run(workload(batch * n, tokens), &mut |_| {})?.total;
            let total_secs = out.decode_secs + out.prefill_secs;
            let tps = if total_secs > 0.0 { out.tokens as f64 / total_secs } else { 0.0 };
            if n == 1 {
                single_tps = tps;
            }
            let vs_single = if single_tps > 0.0 { tps / single_tps } else { 1.0 };
            let vs_dense = if dense_tps[1] > 0.0 { tps / dense_tps[1] } else { 1.0 };
            let label = format!("replicas-{n}");
            println!(
                "  {label:<8} {:<8} {n} engines  {} tok in {total_secs:.3}s -> {tps:.1} tok/s \
                 ({vs_single:.2}x single-replica)",
                "cached", out.tokens
            );
            table.row(vec![
                label.clone(),
                "cached".to_string(),
                format!("{:.3}", model.density()),
                format!("{:.2}", model.effective_bits()),
                out.tokens.to_string(),
                format!("{total_secs:.3}"),
                format!("{tps:.1}"),
                format!("{vs_dense:.2}x"),
                format!("{vs_single:.2}x vs 1-rep"),
            ]);
            rows.push(obj(vec![
                ("variant", Json::Str(label)),
                ("kv", Json::Str("cached".into())),
                ("replicas", Json::Num(n as f64)),
                ("density", Json::Num(model.density())),
                ("effective_bits", Json::Num(model.effective_bits())),
                ("bytes_per_weight", Json::Num(model.effective_bits() / 8.0)),
                ("tokens", Json::Num(out.tokens as f64)),
                ("decode_secs", Json::Num(out.decode_secs)),
                ("prefill_secs", Json::Num(out.prefill_secs)),
                ("tokens_per_sec", Json::Num(tps)),
                ("speedup_vs_dense", Json::Num(vs_dense)),
                ("speedup_vs_single_replica", Json::Num(vs_single)),
            ]));
        }
    }

    let report_dir = std::env::var_os("SPARSEGPT_REPORTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| "reports".into());
    std::fs::create_dir_all(&report_dir)?;
    print!("{}", table.render());
    table.save(&report_dir, "serve_throughput")?;
    let doc = obj(vec![
        ("bench", Json::Str("serve_throughput".into())),
        ("config", Json::Str(config.clone())),
        ("workers", Json::Num(WorkerPool::global().workers() as f64)),
        ("requests", Json::Num(requests as f64)),
        ("max_new_tokens", Json::Num(tokens as f64)),
        ("prompt_len", Json::Num(prompt_len as f64)),
        ("rows", Json::Arr(rows)),
        ("metrics", obs.snapshot().to_json()),
    ]);
    let text = doc.to_string_pretty();
    std::fs::write("BENCH_serve.json", &text)?;
    std::fs::write(report_dir.join("BENCH_serve.json"), &text)?;
    println!("(saved BENCH_serve.json + reports/serve_throughput.txt/.csv)");
    Ok(())
}
