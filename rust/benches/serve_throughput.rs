//! Serve throughput: end-to-end tokens/sec of the continuous-batching
//! decode engine — dense vs CSR (50% / 60% unstructured) vs 2:4 packed —
//! the serving-side counterpart of Table 7/8's kernel-level speedups.
//! Runtime depends only on shape + sparsity pattern, so the workload runs
//! on seed-0 random weights and needs no artifacts, data or checkpoints.
//!
//! Writes `BENCH_serve.json` (repo root + a copy under `reports/`) so the
//! bench trajectory is machine-readable:
//!   { "bench": "serve_throughput", "config": ..., "rows": [
//!       { "variant": "csr-60%", "density": ..., "tokens": ...,
//!         "decode_secs": ..., "tokens_per_sec": ..., "speedup": ... }, ...] }
//!
//! Env knobs: SPARSEGPT_BENCH_CONFIGS (default "small"),
//! SPARSEGPT_BENCH_SERVE_REQUESTS (8), SPARSEGPT_BENCH_SERVE_TOKENS (8).

use std::collections::BTreeMap;
use std::path::PathBuf;

use anyhow::{anyhow, Result};
use sparsegpt::bench::{env_configs, env_usize};
use sparsegpt::eval::report::Table;
use sparsegpt::model::init::init_params;
use sparsegpt::model::layout::{FlatParams, PRUNABLE_KINDS};
use sparsegpt::model::ModelCfg;
use sparsegpt::serve::{
    EngineOptions, SchedulerPolicy, ServeEngine, ServeRequest, SparseModel,
};
use sparsegpt::solver::magnitude::{magnitude_prune, magnitude_prune_nm};
use sparsegpt::sparse::{PackFormat, PackPolicy};
use sparsegpt::tensor::Tensor;
use sparsegpt::util::json::Json;
use sparsegpt::util::prng::Rng;

fn prune_all(dense: &FlatParams, f: impl Fn(&Tensor) -> Tensor) -> FlatParams {
    let mut fp = dense.clone();
    for layer in 0..fp.cfg.layers {
        for kind in PRUNABLE_KINDS {
            let w = fp.get_linear(kind, layer).unwrap();
            fp.set_linear(kind, layer, &f(&w)).unwrap();
        }
    }
    fp
}

fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect::<BTreeMap<_, _>>())
}

fn main() -> Result<()> {
    let config = env_configs(&["small"]).remove(0);
    let cfg = ModelCfg::builtin(&config)
        .ok_or_else(|| anyhow!("unknown config {config:?} (expected nano..large)"))?;
    let requests = env_usize("SPARSEGPT_BENCH_SERVE_REQUESTS", 8);
    let tokens = env_usize("SPARSEGPT_BENCH_SERVE_TOKENS", 8);
    let dense = init_params(&cfg, 0);

    // one shared synthetic workload: full batch from step 0, greedy
    // sampling, so every variant decodes an identical schedule
    let workload = || -> Vec<(usize, ServeRequest)> {
        let mut rng = Rng::new(7);
        (0..requests)
            .map(|i| {
                let prompt: Vec<i32> = (0..8).map(|_| rng.below(cfg.vocab) as i32).collect();
                (0, ServeRequest { id: i as u64, prompt, max_new_tokens: tokens, seed: i as u64 })
            })
            .collect()
    };
    let batch = requests.max(1);
    let opts = EngineOptions {
        policy: SchedulerPolicy { max_batch: batch, max_wait: 0, queue_cap: batch },
        temperature: 0.0,
        top_k: 0,
    };

    let variants: Vec<(&str, FlatParams, PackFormat)> = vec![
        ("dense", dense.clone(), PackFormat::Dense),
        ("csr-50%", prune_all(&dense, |w| magnitude_prune(w, 0.5).0), PackFormat::Csr),
        ("csr-60%", prune_all(&dense, |w| magnitude_prune(w, 0.6).0), PackFormat::Csr),
        ("nm-2:4", prune_all(&dense, |w| magnitude_prune_nm(w, 2, 4).0), PackFormat::Nm(2, 4)),
    ];

    println!(
        "serve_throughput: {config}, {requests} requests x {tokens} tokens, batch {requests}"
    );
    let mut table = Table::new(
        &format!("serve throughput ({config}, {requests} req x {tokens} tok)"),
        &["variant", "density", "tokens", "decode s", "tok/s", "speedup"],
    );
    let mut rows = Vec::new();
    let mut dense_tps = 0.0f64;
    for (label, params, fmt) in &variants {
        let model = SparseModel::from_params(params, &PackPolicy::with_format(*fmt))?;
        // warmup step keeps first-touch allocation out of the timing
        let _ = ServeEngine::new(&model, opts).run(
            {
                let mut w = workload();
                w.truncate(1);
                for (_, r) in w.iter_mut() {
                    r.max_new_tokens = 1;
                }
                w
            },
            &mut |_| {},
        )?;
        let out = ServeEngine::new(&model, opts).run(workload(), &mut |_| {})?;
        let tps = out.tokens_per_sec();
        if *label == "dense" {
            dense_tps = tps;
        }
        let speedup = if dense_tps > 0.0 { tps / dense_tps } else { 1.0 };
        println!(
            "  {label:<8} density {:.3}  {} tok in {:.3}s -> {tps:.1} tok/s ({speedup:.2}x)",
            model.density(),
            out.tokens,
            out.decode_secs
        );
        table.row(vec![
            label.to_string(),
            format!("{:.3}", model.density()),
            out.tokens.to_string(),
            format!("{:.3}", out.decode_secs),
            format!("{tps:.1}"),
            format!("{speedup:.2}x"),
        ]);
        rows.push(obj(vec![
            ("variant", Json::Str(label.to_string())),
            ("density", Json::Num(model.density())),
            ("tokens", Json::Num(out.tokens as f64)),
            ("decode_secs", Json::Num(out.decode_secs)),
            ("tokens_per_sec", Json::Num(tps)),
            ("speedup", Json::Num(speedup)),
        ]));
    }

    let report_dir = std::env::var_os("SPARSEGPT_REPORTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| "reports".into());
    std::fs::create_dir_all(&report_dir)?;
    print!("{}", table.render());
    table.save(&report_dir, "serve_throughput")?;
    let doc = obj(vec![
        ("bench", Json::Str("serve_throughput".into())),
        ("config", Json::Str(config.clone())),
        ("requests", Json::Num(requests as f64)),
        ("max_new_tokens", Json::Num(tokens as f64)),
        ("rows", Json::Arr(rows)),
    ]);
    let text = doc.to_string_pretty();
    std::fs::write("BENCH_serve.json", &text)?;
    std::fs::write(report_dir.join("BENCH_serve.json"), &text)?;
    println!("(saved BENCH_serve.json + reports/serve_throughput.txt/.csv)");
    Ok(())
}
