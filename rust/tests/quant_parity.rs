//! Differential quant-parity suite: **quantized packed decode is
//! element-identical to the reference path** — quantize the pruned weights
//! with [`QuantGrid`] (the exact grid the packer builds), materialize the
//! dense f32 matrix, and run the existing dense decode. Pinned for every
//! quantized format (`qdense` / `qcsr` / `qnm`) × sparsity regime
//! {50%, 60%, 2:4, 4:8} × bit width × grid grouping, over arbitrary
//! prompt/batch shapes, and **through KV-cached decode** (composing with
//! the `serve_kv_parity.rs` harness: chunked prefill, ring eviction,
//! cache budgets, staggered arrivals). The attention window is 6 tokens
//! here, so every engine scenario runs far past sliding-window eviction.
//!
//! This makes quantized serving exactly as trustworthy as the packed-vs-
//! dense and KV-parity suites made f32 serving: any drift between the
//! dequant-fused kernels and `QuantGrid::decode`'s f32 op order fails
//! these tests bitwise.

use sparsegpt::model::init::init_params;
use sparsegpt::model::layout::{FlatParams, PRUNABLE_KINDS};
use sparsegpt::model::{ModelCfg, SparseStore};
use sparsegpt::serve::{EngineOptions, SchedulerPolicy, ServeEngine, ServeRequest, SparseModel};
use sparsegpt::solver::magnitude::{magnitude_prune, magnitude_prune_nm};
use sparsegpt::solver::quant::QuantGrid;
use sparsegpt::sparse::{PackFormat, PackPolicy};
use sparsegpt::tensor::Tensor;
use sparsegpt::util::prng::Rng;

fn cfg() -> ModelCfg {
    ModelCfg::from_dims("quant-parity", 8, 2, 2, 1, 1, 13, 6)
}

/// Prune every prunable linear of a fresh model with `f`.
fn pruned_params(cfg: &ModelCfg, seed: u64, f: impl Fn(&Tensor) -> Tensor) -> FlatParams {
    let mut fp = init_params(cfg, seed);
    for layer in 0..cfg.layers {
        for kind in PRUNABLE_KINDS {
            let w = f(&fp.get_linear(kind, layer).unwrap());
            fp.set_linear(kind, layer, &w).unwrap();
        }
    }
    fp
}

/// The issue's sparsity regimes; the flag marks n:m regimes (qnm-packable).
fn regimes() -> Vec<(&'static str, FlatParams, bool)> {
    let cfg = cfg();
    vec![
        ("50%", pruned_params(&cfg, 3, |w| magnitude_prune(w, 0.5).0), false),
        ("60%", pruned_params(&cfg, 4, |w| magnitude_prune(w, 0.6).0), false),
        ("2:4", pruned_params(&cfg, 5, |w| magnitude_prune_nm(w, 2, 4).0), true),
        ("4:8", pruned_params(&cfg, 6, |w| magnitude_prune_nm(w, 4, 8).0), true),
    ]
}

/// Quantized formats exercised per regime: every kind, mixed bit widths,
/// per-row and grouped grids.
fn formats(nm: bool) -> Vec<PackFormat> {
    let mut v = vec![
        PackFormat::QDense { bits: 4, group: 0 },
        PackFormat::QCsr { bits: 3, group: 0 },
        PackFormat::QCsr { bits: 4, group: 4 },
        PackFormat::QCsr { bits: 8, group: 0 },
    ];
    if nm {
        v.push(PackFormat::QNm { bits: 4, group: 0 });
        v.push(PackFormat::QNm { bits: 8, group: 4 });
    }
    v
}

/// The reference path of the contract: quantize surviving weights with the
/// same grid the packer builds (per matrix, zeros included in the min/max
/// fold), keep pruned zeros exact, return dense f32 params.
fn quantize_reference(fp: &FlatParams, fmt: PackFormat) -> FlatParams {
    let (bits, group) = match fmt {
        PackFormat::QDense { bits, group }
        | PackFormat::QCsr { bits, group }
        | PackFormat::QNm { bits, group } => (bits, group),
        other => panic!("not a quantized format: {}", other.label()),
    };
    let levels = (1u32 << bits) - 1;
    let mut out = fp.clone();
    for layer in 0..fp.cfg.layers {
        for kind in PRUNABLE_KINDS {
            let w = fp.get_linear(kind, layer).unwrap();
            let grid = QuantGrid::from_weights_grouped(&w, levels, group);
            out.set_linear(kind, layer, &grid.quantize_surviving(&w)).unwrap();
        }
    }
    out
}

fn quantized_and_reference_models(fp: &FlatParams, fmt: PackFormat) -> (SparseModel, SparseModel) {
    let q = SparseModel::from_params(fp, &PackPolicy::with_format(fmt)).unwrap();
    let reference = quantize_reference(fp, fmt);
    let d = SparseModel::from_params(&reference, &PackPolicy::with_format(PackFormat::Dense))
        .unwrap();
    (q, d)
}

/// Random workload for the engine-level runs: mixed prompt lengths
/// (1 .. 3*seq, so some prompts alone overflow the ring), staggered
/// arrivals, mixed token budgets.
fn workload(rng: &mut Rng, vocab: usize, seq: usize) -> Vec<(usize, ServeRequest)> {
    let n = 1 + rng.below(5);
    (0..n)
        .map(|i| {
            let plen = 1 + rng.below(3 * seq);
            let prompt: Vec<i32> = (0..plen).map(|_| rng.below(vocab) as i32).collect();
            (
                rng.below(4),
                ServeRequest {
                    id: i as u64,
                    prompt,
                    max_new_tokens: 1 + rng.below(2 * seq),
                    seed: rng.next_u64(),
                    model: None,
                },
            )
        })
        .collect()
}

fn token_streams(
    model: &SparseModel,
    opts: EngineOptions,
    reqs: Vec<(usize, ServeRequest)>,
) -> Vec<(u64, Vec<i32>)> {
    let mut out: Vec<(u64, Vec<i32>)> = ServeEngine::new(model, opts)
        .run(reqs, &mut |_| {})
        .unwrap()
        .finished
        .iter()
        .map(|f| (f.id, f.tokens.clone()))
        .collect();
    out.sort_by_key(|(id, _)| *id);
    out
}

#[test]
fn quantized_packed_decode_matches_quantize_then_dense_reference() {
    // the core contract, on the uncached banded re-forward path: arbitrary
    // batch shapes and context lengths (incl. past the attention window)
    for (regime, fp, nm) in regimes() {
        let cfg = &fp.cfg;
        for fmt in formats(nm) {
            let (q, d) = quantized_and_reference_models(&fp, fmt);
            let mut rng = Rng::new(0x5EED ^ 0x51);
            for trial in 0..4 {
                let batch = 1 + rng.below(3);
                let seqs: Vec<Vec<i32>> = (0..batch)
                    .map(|_| {
                        let len = 1 + rng.below(3 * cfg.seq);
                        (0..len).map(|_| rng.below(cfg.vocab) as i32).collect()
                    })
                    .collect();
                let seqs: Vec<&[i32]> = seqs.iter().map(|s| s.as_slice()).collect();
                let want = d.forward_logits(&seqs).unwrap();
                let got = q.forward_logits(&seqs).unwrap();
                assert_eq!(
                    want.data(),
                    got.data(),
                    "{regime} {} trial {trial}: quantized decode diverged",
                    fmt.label()
                );
            }
        }
    }
}

#[test]
fn quantized_model_level_kv_logits_are_bitwise_identical() {
    // below the engine: prefill + one incremental step equals the banded
    // full re-forward bit-for-bit at every context length around and past
    // the eviction horizon, on the quantized kernels
    for (regime, fp, nm) in regimes() {
        let cfg = fp.cfg.clone();
        for fmt in formats(nm) {
            let q = SparseModel::from_params(&fp, &PackPolicy::with_format(fmt)).unwrap();
            let mut rng = Rng::new(0xBEEF);
            let ctx: Vec<i32> =
                (0..3 * cfg.seq + 2).map(|_| rng.below(cfg.vocab) as i32).collect();
            for len in 1..=ctx.len() {
                let want = q.forward_logits(&[&ctx[..len]]).unwrap();
                let mut cache = q.new_cache();
                let logits = if len == 1 {
                    q.prefill(&ctx[..1], &mut cache, 2).unwrap().0
                } else {
                    q.prefill(&ctx[..len - 1], &mut cache, 2).unwrap();
                    q.decode_cached(&[ctx[len - 1]], &mut [&mut cache]).unwrap().0.into_data()
                };
                assert_eq!(want.data(), &logits[..], "{regime} {} len {len}", fmt.label());
            }
        }
    }
}

#[test]
fn quantized_cached_decode_matches_reforward_through_the_engine() {
    // the KV-parity harness composed onto quantized models: cached and
    // uncached modes must emit identical token streams under random
    // policies, chunk sizes, and cache budgets
    for (regime, fp, nm) in regimes() {
        for fmt in formats(nm) {
            let model = SparseModel::from_params(&fp, &PackPolicy::with_format(fmt)).unwrap();
            let (vocab, seq) = (model.cfg.vocab, model.cfg.seq);
            for seed in 0..4u64 {
                let mut rng = Rng::new(seed ^ 0x9A17);
                let reqs = workload(&mut rng, vocab, seq);
                let policy = SchedulerPolicy {
                    max_batch: 1 + rng.below(4),
                    max_wait: rng.below(3),
                    queue_cap: 16,
                    max_prefill_tokens: [0, seq][rng.below(2)],
                };
                let base = EngineOptions {
                    policy,
                    temperature: [0.0, 0.9][rng.below(2)],
                    top_k: 4,
                    prefill_chunk: [0, 1, 2, 5][rng.below(4)],
                    cache_budget_bytes: [0, model.cache_bytes()][rng.below(2)],
                    kv_cache: true,
                    ..EngineOptions::default()
                };
                let cached = token_streams(&model, base, reqs.clone());
                let uncached =
                    token_streams(&model, EngineOptions { kv_cache: false, ..base }, reqs);
                assert_eq!(
                    cached,
                    uncached,
                    "{regime} {} seed {seed}: cached quantized decode diverged",
                    fmt.label()
                );
                assert!(
                    cached.iter().any(|(_, t)| !t.is_empty()),
                    "{regime} {} seed {seed}: workload produced no tokens",
                    fmt.label()
                );
            }
        }
    }
}

#[test]
fn quantized_and_reference_models_agree_on_the_cached_path() {
    // cross-model KV parity: the quantized packing and the quantize-then-
    // dense reference packing of the same weights decode identical token
    // streams through per-request KV caches
    for (regime, fp, nm) in regimes() {
        for fmt in formats(nm) {
            let (q, d) = quantized_and_reference_models(&fp, fmt);
            let mut rng = Rng::new(0x77C5);
            let reqs = workload(&mut rng, fp.cfg.vocab, fp.cfg.seq);
            let opts =
                EngineOptions { temperature: 0.0, top_k: 0, ..EngineOptions::default() };
            assert_eq!(
                token_streams(&q, opts, reqs.clone()),
                token_streams(&d, opts, reqs),
                "{regime} {}",
                fmt.label()
            );
        }
    }
}

#[test]
fn spkt_v2_file_roundtrip_preserves_quantized_decode() {
    // prune -> quantized pack -> save -> load -> serve must decode exactly
    // like the in-memory packing, with the quant metadata intact
    let dir = std::env::temp_dir().join(format!("sgpt_quant_parity_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    for (regime, fp, nm) in regimes() {
        let cfg = fp.cfg.clone();
        for fmt in formats(nm) {
            let policy = PackPolicy::with_format(fmt);
            let store = SparseStore::pack(&fp, &policy, "quant-parity").unwrap();
            let safe = fmt.label().replace(':', "_").replace(',', "_");
            let path = dir.join(format!("{regime}-{safe}.spkt"));
            store.save(&path).unwrap();
            let back = SparseStore::load(&path).unwrap();
            assert_eq!(back.effective_bits(), store.effective_bits(), "{regime} {}", fmt.label());
            let m1 = SparseModel::from_store(&back, &cfg).unwrap();
            let m2 = SparseModel::from_params(&fp, &policy).unwrap();
            let mut rng = Rng::new(0xF11E);
            let (a, b): (Vec<i32>, Vec<i32>) = (
                (0..5).map(|_| rng.below(cfg.vocab) as i32).collect(),
                (0..2 * cfg.seq).map(|_| rng.below(cfg.vocab) as i32).collect(),
            );
            let seqs: Vec<&[i32]> = vec![&a, &b];
            assert_eq!(
                m1.forward_logits(&seqs).unwrap(),
                m2.forward_logits(&seqs).unwrap(),
                "{regime} {}",
                fmt.label()
            );
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn effective_bits_hit_the_fig6_point_on_the_served_model() {
    // the paper's headline size argument, measured on the serving path:
    // 50% sparse + 4-bit + bitmask = 3.0 bits/weight (well under the 3.1
    // acceptance ceiling); q8 lands at 5.0
    let cfg = cfg();
    let fp = pruned_params(&cfg, 9, |w| magnitude_prune(w, 0.5).0);
    let q4 = SparseModel::from_params(
        &fp,
        &PackPolicy::with_format(PackFormat::QCsr { bits: 4, group: 0 }),
    )
    .unwrap();
    assert!((q4.effective_bits() - 3.0).abs() < 1e-9, "{}", q4.effective_bits());
    assert!(q4.effective_bits() <= 3.1, "acceptance ceiling");
    let q8 = SparseModel::from_params(
        &fp,
        &PackPolicy::with_format(PackFormat::QDense { bits: 8, group: 0 }),
    )
    .unwrap();
    assert!((q8.effective_bits() - 5.0).abs() < 1e-9);
}
