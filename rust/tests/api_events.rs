//! The JSON event stream contract: a nano `Prune` job's event sequence
//! serializes to exactly the golden JSON-lines schema (one parseable
//! object per line, every object carrying a `reason` field), and stays
//! byte-stable — downstream consumers parse these lines.

use sparsegpt::api::{Event, EventSink, JsonlSink, MemorySink};
use sparsegpt::util::json::Json;

/// The canonical event sequence of a nano `Prune` job (fixed values; the
/// live pipeline emits the same shapes with measured numbers).
fn nano_prune_events() -> Vec<Event> {
    vec![
        Event::JobStarted {
            job: "prune".into(),
            label: "prune/nano/sparsegpt-50%".into(),
            config: Some("nano".into()),
        },
        Event::Message {
            text: "[prune nano] method sparsegpt-50% | 8 calib segments | damp 0.01".into(),
        },
        Event::MatrixReport {
            layer: 0,
            kind: "q".into(),
            sparsity: 0.5,
            skipped: false,
            solver_secs: 0.25,
            sq_error: None,
        },
        Event::MatrixReport {
            layer: 0,
            kind: "fc1".into(),
            sparsity: 0.5,
            skipped: false,
            solver_secs: 0.5,
            sq_error: Some(0.125),
        },
        Event::MatrixReport {
            layer: 1,
            kind: "fc2".into(),
            sparsity: 0.0,
            skipped: true,
            solver_secs: 0.0,
            sq_error: None,
        },
        Event::BlockCompressed { layer: 0, layers: 2, sparsity: 0.5, secs: 1.5 },
        Event::EvalResult { dataset: "synth-wiki".into(), ppl: 42.5, tokens: 1024 },
        Event::CheckpointSaved { path: "checkpoints/nano-sparsegpt-50%.ckpt".into() },
        Event::JobFinished { job: "prune".into(), ok: true, secs: 3.5 },
    ]
}

#[test]
fn nano_prune_event_stream_matches_golden() {
    let mut sink = JsonlSink::new(Vec::new());
    for ev in nano_prune_events() {
        sink.emit(&ev);
    }
    let got = String::from_utf8(sink.into_inner()).unwrap();
    let want = include_str!("golden/prune_events.jsonl");
    assert_eq!(
        got, want,
        "JSON event schema drifted — update rust/tests/golden/prune_events.jsonl deliberately \
         (downstream consumers parse these lines)"
    );
}

#[test]
fn every_line_parses_with_reason_field() {
    let mut sink = JsonlSink::new(Vec::new());
    for ev in nano_prune_events() {
        sink.emit(&ev);
    }
    let got = String::from_utf8(sink.into_inner()).unwrap();
    let mut reasons = Vec::new();
    for line in got.lines() {
        let v = Json::parse(line).unwrap_or_else(|e| panic!("unparseable line {line:?}: {e:#}"));
        reasons.push(v.get("reason").unwrap().as_str().unwrap().to_string());
    }
    assert_eq!(
        reasons,
        vec![
            "job-started",
            "message",
            "matrix-report",
            "matrix-report",
            "matrix-report",
            "block-compressed",
            "eval-result",
            "checkpoint-saved",
            "job-finished",
        ]
    );
}

#[test]
fn json_and_memory_sinks_agree_on_event_count() {
    let mut mem = MemorySink::new();
    let mut jsonl = JsonlSink::new(Vec::new());
    for ev in nano_prune_events() {
        mem.emit(&ev);
        jsonl.emit(&ev);
    }
    let text = String::from_utf8(jsonl.into_inner()).unwrap();
    assert_eq!(mem.events.len(), text.lines().count());
    // reasons agree pairwise
    for (ev, line) in mem.events.iter().zip(text.lines()) {
        let v = Json::parse(line).unwrap();
        assert_eq!(v.get("reason").unwrap().as_str().unwrap(), ev.reason());
    }
}

/// The serve-side lifecycle events added for the TCP front door keep a
/// byte-stable wire shape (both network and synthetic runs emit them).
#[test]
fn serve_lifecycle_events_serialize_stably() {
    let cases = [
        (
            Event::RequestCancelled { id: 1, step: 9, tokens: 4 },
            r#"{"id":1,"reason":"request-cancelled","step":9,"tokens":4}"#,
        ),
        (
            Event::RequestRejected { id: 2, step: 9, queue: 64, cap: 64 },
            r#"{"cap":64,"id":2,"queue":64,"reason":"request-rejected","step":9}"#,
        ),
        (
            Event::ServeListening { addr: "127.0.0.1:7070".into() },
            r#"{"addr":"127.0.0.1:7070","reason":"serve-listening"}"#,
        ),
        (
            Event::EngineDrained {
                steps: 20,
                requests: 2,
                tokens: 32,
                tokens_per_sec: 64.0,
                cancelled: 1,
                cache_bytes_in_use: 0,
            },
            r#"{"cache_bytes_in_use":0,"cancelled":1,"reason":"engine-drained","requests":2,"steps":20,"tokens":32,"tokens_per_sec":64}"#,
        ),
    ];
    for (ev, want) in cases {
        assert_eq!(ev.to_json().to_string_compact(), want);
    }
}

/// Non-finite values (a diverged perplexity) must stay valid JSON.
#[test]
fn non_finite_values_serialize_as_null() {
    let ev = Event::EvalResult { dataset: "synth-wiki".into(), ppl: f64::INFINITY, tokens: 0 };
    let line = ev.to_json().to_string_compact();
    let v = Json::parse(&line).unwrap();
    assert_eq!(v.get("ppl").unwrap(), &Json::Null);
}
