//! Differential test for the tentpole serving invariant: **KV-cached
//! incremental decode is token-for-token identical to the full re-forward
//! path** — for arbitrary prompt lengths (including prompts longer than
//! the attention window, so prefill itself evicts), arbitrary batch
//! shapes/arrival patterns, every packed format (CSR / 2:4 / dense), every
//! prefill chunk size, and with a cache-memory budget constraining
//! admission. The window is 6 tokens here, so every scenario runs far past
//! sliding-window eviction.

use sparsegpt::model::init::init_params;
use sparsegpt::model::layout::{FlatParams, PRUNABLE_KINDS};
use sparsegpt::model::ModelCfg;
use sparsegpt::serve::{EngineOptions, SchedulerPolicy, ServeEngine, ServeRequest, SparseModel};
use sparsegpt::solver::magnitude::{magnitude_prune, magnitude_prune_nm};
use sparsegpt::sparse::{PackFormat, PackPolicy};
use sparsegpt::util::prng::Rng;

const TRIALS: u64 = 8;

fn cfg() -> ModelCfg {
    ModelCfg::from_dims("kv-parity", 8, 2, 2, 1, 1, 13, 6)
}

/// Prune every prunable linear of a fresh model with `f`.
fn pruned_params(
    cfg: &ModelCfg,
    seed: u64,
    f: impl Fn(&sparsegpt::tensor::Tensor) -> sparsegpt::tensor::Tensor,
) -> FlatParams {
    let mut fp = init_params(cfg, seed);
    for layer in 0..cfg.layers {
        for kind in PRUNABLE_KINDS {
            let w = f(&fp.get_linear(kind, layer).unwrap());
            fp.set_linear(kind, layer, &w).unwrap();
        }
    }
    fp
}

/// One model per packed format, all over magnitude-pruned weights.
fn models() -> Vec<(&'static str, SparseModel)> {
    let cfg = cfg();
    let unstructured = pruned_params(&cfg, 3, |w| magnitude_prune(w, 0.5).0);
    let nm = pruned_params(&cfg, 4, |w| magnitude_prune_nm(w, 2, 4).0);
    vec![
        (
            "dense",
            SparseModel::from_params(&unstructured, &PackPolicy::with_format(PackFormat::Dense))
                .unwrap(),
        ),
        (
            "csr",
            SparseModel::from_params(&unstructured, &PackPolicy::with_format(PackFormat::Csr))
                .unwrap(),
        ),
        (
            "nm-2:4",
            SparseModel::from_params(&nm, &PackPolicy::with_format(PackFormat::Nm(2, 4)))
                .unwrap(),
        ),
    ]
}

/// Random workload: mixed prompt lengths (1 .. 3*seq, so some prompts
/// alone overflow the ring), staggered arrivals, mixed token budgets.
fn workload(rng: &mut Rng, vocab: usize, seq: usize) -> Vec<(usize, ServeRequest)> {
    let n = 1 + rng.below(5);
    (0..n)
        .map(|i| {
            let plen = 1 + rng.below(3 * seq);
            let prompt: Vec<i32> = (0..plen).map(|_| rng.below(vocab) as i32).collect();
            (
                rng.below(4),
                ServeRequest {
                    id: i as u64,
                    prompt,
                    max_new_tokens: 1 + rng.below(2 * seq),
                    seed: rng.next_u64(),
                    model: None,
                },
            )
        })
        .collect()
}

fn token_streams(
    model: &SparseModel,
    opts: EngineOptions,
    reqs: Vec<(usize, ServeRequest)>,
) -> Vec<(u64, Vec<i32>)> {
    let mut out: Vec<(u64, Vec<i32>)> = ServeEngine::new(model, opts)
        .run(reqs, &mut |_| {})
        .unwrap()
        .finished
        .iter()
        .map(|f| (f.id, f.tokens.clone()))
        .collect();
    out.sort_by_key(|(id, _)| *id);
    out
}

#[test]
fn cached_decode_matches_reforward_on_all_packed_formats() {
    for (label, model) in models() {
        let (vocab, seq) = (model.cfg.vocab, model.cfg.seq);
        for seed in 0..TRIALS {
            let mut rng = Rng::new(seed ^ 0x5EED);
            let reqs = workload(&mut rng, vocab, seq);
            let policy = SchedulerPolicy {
                max_batch: 1 + rng.below(4),
                max_wait: rng.below(3),
                queue_cap: 16,
                max_prefill_tokens: [0, seq][rng.below(2)],
            };
            let temperature = [0.0, 0.9][rng.below(2)];
            let chunk = [0, 1, 2, 5][rng.below(4)];
            // a tight cache budget reshuffles the admission schedule but
            // must never change what any request decodes
            let cache_budget_bytes = [0, model.cache_bytes()][rng.below(2)];
            let base = EngineOptions {
                policy,
                temperature,
                top_k: 4,
                prefill_chunk: chunk,
                cache_budget_bytes,
                kv_cache: true,
                ..EngineOptions::default()
            };
            let cached = token_streams(&model, base, reqs.clone());
            let uncached =
                token_streams(&model, EngineOptions { kv_cache: false, ..base }, reqs);
            assert_eq!(
                cached, uncached,
                "{label} seed {seed}: cached decode diverged from the re-forward path"
            );
            assert!(
                cached.iter().any(|(_, t)| !t.is_empty()),
                "{label} seed {seed}: workload produced no tokens"
            );
        }
    }
}

#[test]
fn model_level_logits_are_bitwise_identical_per_format() {
    // below the engine: prefill + one incremental step equals the banded
    // full re-forward bit-for-bit at every context length around and past
    // the eviction horizon, for every packed format
    for (label, model) in models() {
        let (vocab, seq) = (model.cfg.vocab, model.cfg.seq);
        let mut rng = Rng::new(0xBEEF);
        let ctx: Vec<i32> = (0..3 * seq + 2).map(|_| rng.below(vocab) as i32).collect();
        for len in 1..=ctx.len() {
            let want = model.forward_logits(&[&ctx[..len]]).unwrap();
            let mut cache = model.new_cache();
            let logits = if len == 1 {
                model.prefill(&ctx[..1], &mut cache, 2).unwrap().0
            } else {
                model.prefill(&ctx[..len - 1], &mut cache, 2).unwrap();
                model
                    .decode_cached(&[ctx[len - 1]], &mut [&mut cache])
                    .unwrap()
                    .0
                    .into_data()
            };
            assert_eq!(want.data(), &logits[..], "{label} len {len}");
        }
    }
}

#[test]
fn packed_formats_agree_with_each_other_on_the_cached_path() {
    // the PR 3 invariant (packed == dense), re-pinned on the new path: the
    // dense and CSR packings of the same pruned weights decode identical
    // token streams through the KV cache
    let cfg = cfg();
    let fp = pruned_params(&cfg, 9, |w| magnitude_prune(w, 0.6).0);
    let dense =
        SparseModel::from_params(&fp, &PackPolicy::with_format(PackFormat::Dense)).unwrap();
    let csr = SparseModel::from_params(&fp, &PackPolicy::with_format(PackFormat::Csr)).unwrap();
    let mut rng = Rng::new(77);
    let reqs = workload(&mut rng, cfg.vocab, cfg.seq);
    let opts = EngineOptions { temperature: 0.0, top_k: 0, ..EngineOptions::default() };
    assert_eq!(
        token_streams(&dense, opts, reqs.clone()),
        token_streams(&csr, opts, reqs)
    );
}
