//! Differential test for the network front door invariant: **token streams
//! served over TCP are byte-identical to an in-process engine run** — for
//! every packed format (dense / CSR / quantized n:m), with three clients
//! streaming concurrently, and with one client disconnecting mid-stream.
//! Per-request streams depend only on (prompt, seed, max_new_tokens) — the
//! kernels are row-independent, sampling uses a per-request rng, and
//! attention is banded per request — so batch composition (and therefore
//! network arrival nondeterminism) must never change what any client
//! receives. After the mid-stream disconnect the engine must drain with
//! every [`CacheBudget`] reservation returned (`cache_bytes_in_use == 0`).
//!
//! [`CacheBudget`]: sparsegpt::serve::CacheBudget

use std::collections::BTreeMap;
use std::time::Duration;

use sparsegpt::model::init::init_params;
use sparsegpt::model::layout::{FlatParams, PRUNABLE_KINDS};
use sparsegpt::model::ModelCfg;
use sparsegpt::serve::net::{
    run_client, send_shutdown, ClientOptions, ClientRequest, NetServer, NetServerOptions,
};
use sparsegpt::serve::{EngineOptions, SchedulerPolicy, ServeEngine, ServeRequest, SparseModel};
use sparsegpt::solver::magnitude::{magnitude_prune, magnitude_prune_nm};
use sparsegpt::sparse::{PackFormat, PackPolicy};
use sparsegpt::util::prng::Rng;

fn cfg() -> ModelCfg {
    ModelCfg::from_dims("net-parity", 8, 2, 2, 1, 1, 13, 6)
}

/// Prune every prunable linear of a fresh model with `f`.
fn pruned_params(
    cfg: &ModelCfg,
    seed: u64,
    f: impl Fn(&sparsegpt::tensor::Tensor) -> sparsegpt::tensor::Tensor,
) -> FlatParams {
    let mut fp = init_params(cfg, seed);
    for layer in 0..cfg.layers {
        for kind in PRUNABLE_KINDS {
            let w = f(&fp.get_linear(kind, layer).unwrap());
            fp.set_linear(kind, layer, &w).unwrap();
        }
    }
    fp
}

/// One model per packed format: f32 dense and CSR over unstructured
/// pruning, plus the quantized n:m packing (the `.spkt` v2 serving leg).
fn models() -> Vec<(&'static str, SparseModel)> {
    let cfg = cfg();
    let unstructured = pruned_params(&cfg, 3, |w| magnitude_prune(w, 0.5).0);
    let nm = pruned_params(&cfg, 4, |w| magnitude_prune_nm(w, 2, 4).0);
    let qnm_policy = PackPolicy::with_format(PackFormat::QNm { bits: 4, group: 0 });
    vec![
        (
            "dense",
            SparseModel::from_params(&unstructured, &PackPolicy::with_format(PackFormat::Dense))
                .unwrap(),
        ),
        (
            "csr",
            SparseModel::from_params(&unstructured, &PackPolicy::with_format(PackFormat::Csr))
                .unwrap(),
        ),
        ("qnm-4bit", SparseModel::from_params(&nm, &qnm_policy).unwrap()),
    ]
}

/// The reference: the same request served by the engine without a socket
/// in sight (alone — per-request streams are batch-independent).
fn expected_stream(model: &SparseModel, opts: EngineOptions, r: &ClientRequest) -> Vec<i32> {
    let req = ServeRequest {
        id: 0,
        prompt: r.prompt.clone(),
        max_new_tokens: r.max_new_tokens,
        seed: r.seed,
        model: None,
    };
    let out = ServeEngine::new(model, opts).run(vec![(0, req)], &mut |_| {}).unwrap();
    out.finished[0].tokens.clone()
}

fn client(tag: &str, prompt: Vec<i32>, max_new_tokens: usize, seed: u64) -> ClientRequest {
    ClientRequest { tag: Some(tag.to_string()), prompt, max_new_tokens, seed, model: None }
}

#[test]
fn tcp_streams_match_in_process_run_across_formats() {
    for (label, model) in models() {
        let vocab = model.cfg.vocab;
        let mut rng = Rng::new(0xA11CE);
        let mut prompt = |len: usize| -> Vec<i32> {
            (0..len).map(|_| rng.below(vocab) as i32).collect()
        };
        // three concurrent clients; c2 disconnects after 2 of 64 tokens
        let c0 = vec![client("c0-0", prompt(4), 5, 11), client("c0-1", prompt(9), 7, 12)];
        let c1 = vec![client("c1-0", prompt(14), 6, 13)];
        let c2 = vec![client("c2-0", prompt(5), 64, 14)];
        let opts = EngineOptions {
            temperature: 0.7,
            top_k: 4,
            // two cache slots for four requests: admission defers joins, so
            // the server-side batch schedule differs from the solo runs —
            // the streams must not care
            cache_budget_bytes: 2 * model.cache_bytes(),
            ..EngineOptions::default()
        };
        let mut expect: BTreeMap<String, Vec<i32>> = BTreeMap::new();
        for r in c0.iter().chain(c1.iter()).chain(c2.iter()) {
            expect.insert(r.tag.clone().unwrap(), expected_stream(&model, opts, r));
        }

        let srv_opts = NetServerOptions::new("net-parity".into(), vocab);
        let srv = NetServer::bind("127.0.0.1:0", srv_opts).unwrap();
        let addr = srv.local_addr().to_string();
        let coordinator = {
            let addr = addr.clone();
            let (c0, c1, c2) = (c0.clone(), c1.clone(), c2.clone());
            std::thread::spawn(move || {
                let spawn = |reqs: Vec<ClientRequest>, o: ClientOptions| {
                    let addr = addr.clone();
                    std::thread::spawn(move || run_client(&addr, &reqs, &o, &mut |_| {}).unwrap())
                };
                let h0 = spawn(c0, ClientOptions::default());
                let h1 = spawn(c1, ClientOptions::default());
                let h2 = spawn(
                    c2,
                    ClientOptions { disconnect_after: Some(2), ..Default::default() },
                );
                let outs = (h0.join().unwrap(), h1.join().unwrap(), h2.join().unwrap());
                // every client resolved (or dropped): drain the server
                send_shutdown(&addr, Duration::from_secs(30)).unwrap();
                outs
            })
        };
        let outcome = srv.serve(&model, opts, &mut |_| {}).unwrap();
        let (o0, o1, o2) = coordinator.join().unwrap();

        // per connection, accepted order == submission order (one reader
        // thread processes that socket's frames in order), so zip by index
        for (out, reqs) in [(&o0, &c0), (&o1, &c1)] {
            assert_eq!(out.accepted.len(), reqs.len(), "{label}: all accepted");
            assert_eq!(out.finished.len(), reqs.len(), "{label}: all finished");
            for (i, r) in reqs.iter().enumerate() {
                let got = out.streams.get(&out.accepted[i]).unwrap();
                let want = &expect[r.tag.as_deref().unwrap()];
                assert_eq!(
                    got, want,
                    "{label} {:?}: wire stream differs from the in-process run",
                    r.tag
                );
            }
        }
        // the disconnector saw an exact prefix of its stream before it
        // dropped the socket cold
        assert!(o2.disconnected, "{label}: disconnect_after must trip");
        let got2 = o2.streams.get(&o2.accepted[0]).unwrap();
        assert_eq!(got2.len(), 2, "{label}: dropped after 2 token frames");
        assert_eq!(&expect["c2-0"][..2], &got2[..], "{label}: prefix parity before disconnect");
        // server side: the disconnect retired as cancellation mid-stream,
        // and the drain returned every cache reservation to the budget
        assert_eq!(outcome.finished.len(), 3, "{label}: surviving requests finish");
        assert_eq!(outcome.cancelled, 1, "{label}: one disconnect, one cancel");
        assert_eq!(outcome.rejected, 0, "{label}");
        assert_eq!(outcome.cache_bytes_in_use, 0, "{label}: budget back to zero");
        assert!(
            outcome.peak_cache_bytes <= 2 * model.cache_bytes(),
            "{label}: admission never exceeded the two-slot budget"
        );
    }
}

#[test]
fn overflowing_burst_is_rejected_with_429_semantics() {
    // a one-slot queue in front of a one-slot batch, hit with an 8-request
    // burst from a single connection: the queue can only drain one request
    // per multi-step decode, so most of the burst must come back as
    // `rejected` frames — and the engine must never block or drop silently
    let (_, model) = models().remove(0);
    let opts = EngineOptions {
        policy: SchedulerPolicy { max_batch: 1, max_wait: 0, queue_cap: 1, max_prefill_tokens: 0 },
        temperature: 0.0,
        top_k: 0,
        ..EngineOptions::default()
    };
    let srv_opts = NetServerOptions::new("net-parity".into(), model.cfg.vocab);
    let srv = NetServer::bind("127.0.0.1:0", srv_opts).unwrap();
    let addr = srv.local_addr().to_string();
    let reqs: Vec<ClientRequest> =
        (0..8).map(|i| client(&format!("b{i}"), vec![1, 2, 3], 6, i)).collect();
    let handle = {
        let addr = addr.clone();
        std::thread::spawn(move || {
            run_client(
                &addr,
                &reqs,
                &ClientOptions { shutdown: true, ..Default::default() },
                &mut |_| {},
            )
            .unwrap()
        })
    };
    let outcome = srv.serve(&model, opts, &mut |_| {}).unwrap();
    let out = handle.join().unwrap();
    assert_eq!(out.finished.len() + out.rejected, 8, "every submission resolves exactly once");
    assert!(out.rejected >= 1, "the burst must overflow the one-slot queue");
    assert_eq!(outcome.rejected, out.rejected, "server and client agree");
    assert_eq!(outcome.finished.len(), out.finished.len());
    assert_eq!(outcome.cancelled, 0);
    assert_eq!(outcome.cache_bytes_in_use, 0);
}
