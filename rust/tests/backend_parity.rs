//! Backend parity: the reference interpreter's solver artifacts must match
//! the pure-Rust f64 reference solver (`solver/sparsegpt_ref.rs`)
//! elementwise on random Hessians — unstructured, 2:4 and 4:8 masks, joint
//! quantization and the Bs ablation — and its linalg artifacts must match
//! the f64 chain. Also covers backend selection order and the cached-
//! literal path.

use sparsegpt::model::config::BUILTIN_BLOCKSIZE;
use sparsegpt::runtime::{ArgValue, Backend, BackendKind, ReferenceBackend};
use sparsegpt::solver::hessian::dampened_hinv_chol_f64;
use sparsegpt::solver::sparsegpt_ref::{ref_sparsegpt, Pattern};
use sparsegpt::tensor::Tensor;
use sparsegpt::util::prng::Rng;

const TOL: f32 = 1e-5;

fn problem(seed: u64, r: usize, c: usize) -> (Tensor, Tensor, Tensor) {
    let mut rng = Rng::new(seed);
    let w = Tensor::new(vec![r, c], (0..r * c).map(|_| rng.normal_f32()).collect());
    let n = 2 * c;
    let x = Tensor::new(vec![n, c], (0..n * c).map(|_| rng.normal_f32()).collect());
    let h = x.transpose2().matmul(&x);
    let hc = dampened_hinv_chol_f64(&h, 0.01).expect("hinv chol");
    (w, h, hc)
}

fn assert_close(got: &Tensor, want: &Tensor, what: &str) {
    assert_eq!(got.shape(), want.shape(), "{what}: shape");
    for (i, (a, b)) in got.data().iter().zip(want.data()).enumerate() {
        assert!((a - b).abs() <= TOL, "{what}: element {i}: {a} vs {b}");
    }
}

#[test]
fn unstructured_solver_matches_reference_solver() {
    let be = ReferenceBackend::new();
    for (seed, (r, c)) in [(0u64, (32usize, 64usize)), (1, (64, 64)), (2, (48, 96))] {
        let (w, _h, hc) = problem(seed, r, c);
        for p in [0.25f32, 0.5, 0.75] {
            let out = be
                .run(
                    &format!("sparsegpt_{r}x{c}"),
                    &[
                        ArgValue::F32(w.data()),
                        ArgValue::F32(hc.data()),
                        ArgValue::Scalar(p),
                        ArgValue::Scalar(0.0),
                    ],
                )
                .unwrap();
            let (w_ref, mask_ref) =
                ref_sparsegpt(&w, &hc, Pattern::Unstructured(p as f64), 0, BUILTIN_BLOCKSIZE);
            assert_eq!(out[1].data(), mask_ref.data(), "mask p={p} ({r}x{c})");
            assert_close(&out[0], &w_ref, &format!("weights p={p} ({r}x{c})"));
        }
    }
}

#[test]
fn nm_solvers_match_reference_solver_and_patterns() {
    let be = ReferenceBackend::new();
    let (r, c) = (32, 64);
    let (w, _h, hc) = problem(3, r, c);
    for (artifact, n, m) in [("sparsegpt24", 2usize, 4usize), ("sparsegpt48", 4, 8)] {
        let out = be
            .run(
                &format!("{artifact}_{r}x{c}"),
                &[
                    ArgValue::F32(w.data()),
                    ArgValue::F32(hc.data()),
                    ArgValue::Scalar(0.0),
                ],
            )
            .unwrap();
        let (w_ref, mask_ref) =
            ref_sparsegpt(&w, &hc, Pattern::NM(n, m), 0, BUILTIN_BLOCKSIZE);
        assert_eq!(out[1].data(), mask_ref.data(), "{artifact} mask");
        assert_close(&out[0], &w_ref, artifact);
        // the n:m constraint holds group-by-group
        for row in 0..r {
            for g in (0..c).step_by(m) {
                let kept: f32 = (g..g + m).map(|j| out[1].at2(row, j)).sum();
                assert_eq!(kept as usize, m - n, "{artifact} row {row} group {g}");
            }
        }
    }
}

#[test]
fn joint_quantization_matches_reference_solver() {
    let be = ReferenceBackend::new();
    let (r, c) = (16, 32);
    let (w, _h, hc) = problem(4, r, c);
    let levels = 15.0f32; // 4-bit
    let out = be
        .run(
            &format!("sparsegpt_{r}x{c}"),
            &[
                ArgValue::F32(w.data()),
                ArgValue::F32(hc.data()),
                ArgValue::Scalar(0.5),
                ArgValue::Scalar(levels),
            ],
        )
        .unwrap();
    let (w_ref, mask_ref) =
        ref_sparsegpt(&w, &hc, Pattern::Unstructured(0.5), 15, BUILTIN_BLOCKSIZE);
    assert_eq!(out[1].data(), mask_ref.data());
    assert_close(&out[0], &w_ref, "joint quant");
}

#[test]
fn bs_ablation_variant_uses_requested_blocksize() {
    let be = ReferenceBackend::new();
    let (r, c) = (16, 64);
    let (w, _h, hc) = problem(5, r, c);
    let out = be
        .run(
            &format!("sparsegpt_bs16_{r}x{c}"),
            &[
                ArgValue::F32(w.data()),
                ArgValue::F32(hc.data()),
                ArgValue::Scalar(0.5),
                ArgValue::Scalar(0.0),
            ],
        )
        .unwrap();
    let (w_16, mask_16) = ref_sparsegpt(&w, &hc, Pattern::Unstructured(0.5), 0, 16);
    assert_eq!(out[1].data(), mask_16.data());
    assert_close(&out[0], &w_16, "bs16");
    // and it genuinely differs from the production Bs=128 selection
    let (_, mask_128) = ref_sparsegpt(&w, &hc, Pattern::Unstructured(0.5), 0, 128);
    assert_ne!(mask_16.data(), mask_128.data(), "Bs must change mask selection");
}

#[test]
fn hessian_artifacts_match_f64_chain() {
    let be = ReferenceBackend::new();
    let mut rng = Rng::new(6);
    let dim = 64;
    let n = 2 * dim;
    let x = Tensor::new(vec![n, dim], (0..n * dim).map(|_| rng.normal_f32()).collect());
    let out = be.run(&format!("hessian_{dim}"), &[ArgValue::F32(x.data())]).unwrap();
    let href = x.transpose2().matmul(&x);
    for (a, b) in out[0].data().iter().zip(href.data()) {
        assert!((a - b).abs() <= 1e-3 * (1.0 + b.abs()), "{a} vs {b}");
    }
    let prep = be
        .run(
            &format!("hessian_prep_{dim}"),
            &[ArgValue::F32(href.data()), ArgValue::Scalar(0.01)],
        )
        .unwrap();
    let uref = dampened_hinv_chol_f64(&href, 0.01).unwrap();
    assert_close(&prep[0], &uref, "hessian_prep");
}

#[test]
fn cached_literals_match_direct_buffers() {
    let be = ReferenceBackend::new();
    let cfg = be.config("nano").unwrap();
    let params = sparsegpt::model::init::init_params(&cfg, 0);
    let mut rng = Rng::new(7);
    let toks: Vec<i32> =
        (0..cfg.eval_batch * cfg.seq).map(|_| rng.below(cfg.vocab) as i32).collect();
    let lit = be.cache_f32(&params.data, &[cfg.n_params]).unwrap();
    let a = be
        .run("embed_nano", &[ArgValue::Cached(&lit), ArgValue::I32(&toks)])
        .unwrap();
    let b = be
        .run("embed_nano", &[ArgValue::F32(&params.data), ArgValue::I32(&toks)])
        .unwrap();
    assert_eq!(a[0], b[0]);
    assert_eq!(a[0].shape(), &[cfg.eval_batch, cfg.seq, cfg.d]);
    assert_eq!(be.stats().get("embed_nano").unwrap().runs, 2);
}

#[test]
fn selection_order_cli_beats_env_beats_default() {
    // NOTE: this must remain the ONLY test in this binary that reads or
    // writes SPARSEGPT_BACKEND — the env var is process-global and tests
    // run on parallel threads.
    let orig = std::env::var("SPARSEGPT_BACKEND").ok();
    // explicit always wins, even against a conflicting env var
    std::env::set_var("SPARSEGPT_BACKEND", "reference");
    assert_eq!(BackendKind::resolve(Some(BackendKind::Pjrt)).unwrap(), BackendKind::Pjrt);
    // env wins over the default
    assert_eq!(BackendKind::resolve(None).unwrap(), BackendKind::Reference);
    // a bad env value is a clean error, not a silent default
    std::env::set_var("SPARSEGPT_BACKEND", "quantum");
    assert!(BackendKind::resolve(None).is_err());
    // without either, the compiled-artifact path is the default
    std::env::remove_var("SPARSEGPT_BACKEND");
    assert_eq!(BackendKind::resolve(None).unwrap(), BackendKind::Pjrt);
    if let Some(v) = orig {
        std::env::set_var("SPARSEGPT_BACKEND", v);
    }
}

#[test]
fn malformed_artifacts_and_inputs_error_cleanly() {
    let be = ReferenceBackend::new();
    assert!(be.run("does_not_exist", &[]).is_err());
    assert!(be.run("sparsegpt_64x64", &[ArgValue::F32(&[0.0; 10])]).is_err());
    let (w, _h, hc) = problem(8, 16, 32);
    // wrong factor size
    assert!(be
        .run(
            "sparsegpt_16x32",
            &[
                ArgValue::F32(w.data()),
                ArgValue::F32(&hc.data()[..10]),
                ArgValue::Scalar(0.5),
                ArgValue::Scalar(0.0),
            ],
        )
        .is_err());
}
