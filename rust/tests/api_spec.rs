//! Round-trip tests for the typed job API: every canonical label parses
//! back to the spec that produced it, and parse errors are clean.

use sparsegpt::api::{JobSpec, PruneSpec};
use sparsegpt::coordinator::PruneMethod;
use sparsegpt::solver::sparsegpt_ref::Pattern;

#[test]
fn prune_spec_label_round_trip() {
    for label in [
        "sparsegpt-50%",
        "sparsegpt-80%",
        "sparsegpt-0%",
        "sparsegpt-2:4",
        "sparsegpt-4:8",
        "sparsegpt-2:4+4bit",
        "sparsegpt-4:8+4bit",
        "sparsegpt-50%+3bit",
        "sparsegpt-0%+3bit",
        "sparsegpt-50%-bs64",
        "magnitude-50%",
        "magnitude-80%",
        "magnitude-2:4",
        "magnitude-4:8",
        "adaprune-50%",
    ] {
        let spec = PruneSpec::parse(label).unwrap_or_else(|e| panic!("{label}: {e:#}"));
        assert_eq!(spec.label(), label, "label round trip for {label}");
        assert_eq!(PruneSpec::parse(&spec.label()).unwrap(), spec, "parse round trip");
    }
}

#[test]
fn prune_spec_builders_round_trip_through_labels() {
    let specs = [
        PruneSpec::sparsegpt(0.5),
        PruneSpec::sparsegpt(0.25),
        PruneSpec::sparsegpt(0.625), // non-integer percent: "62.5%"
        PruneSpec::sparsegpt_nm(2, 4),
        PruneSpec::sparsegpt_nm(2, 4).with_quant_bits(4),
        PruneSpec::sparsegpt(0.5).with_quant_bits(3),
        PruneSpec::magnitude(0.8),
        PruneSpec::magnitude_nm(4, 8),
        PruneSpec::adaprune(0.5),
    ];
    for spec in specs {
        assert_eq!(PruneSpec::parse(&spec.label()).unwrap(), spec, "{}", spec.label());
    }
}

#[test]
fn prune_spec_parse_maps_to_methods() {
    assert_eq!(
        PruneSpec::parse("sparsegpt-50%").unwrap().method,
        PruneMethod::SparseGpt { pattern: Pattern::Unstructured(0.5), quant_bits: None }
    );
    assert_eq!(
        PruneSpec::parse("sparsegpt-2:4+4bit").unwrap().method,
        PruneMethod::SparseGpt { pattern: Pattern::NM(2, 4), quant_bits: Some(4) }
    );
    assert_eq!(
        PruneSpec::parse("sparsegpt-50%-bs64").unwrap().method,
        PruneMethod::SparseGptBs { sparsity: 0.5, mask_blocksize: 64 }
    );
    assert_eq!(
        PruneSpec::parse("magnitude-2:4").unwrap().method,
        PruneMethod::Magnitude { pattern: Pattern::NM(2, 4) }
    );
    assert_eq!(
        PruneSpec::parse("adaprune-50%").unwrap().method,
        PruneMethod::AdaPrune { sparsity: 0.5 }
    );
}

#[test]
fn prune_spec_rejects_malformed() {
    for bad in [
        "",
        "sparsegpt",
        "sparsegpt-",
        "bogus-50%",
        "sparsegpt-4:2",
        "sparsegpt-0:4",
        "sparsegpt-50",
        "sparsegpt-150%",
        "sparsegpt-50%+bit",
        "sparsegpt-50%+xbit",
        "sparsegpt-2:4-bs64",
        "adaprune-2:4",
        "magnitude",
    ] {
        assert!(PruneSpec::parse(bad).is_err(), "should reject {bad:?}");
    }
}

#[test]
fn job_spec_label_round_trip() {
    for label in [
        "gen-data",
        "train/nano",
        "prune/nano/sparsegpt-2:4+4bit",
        "prune/small/adaprune-50%",
        "eval/small",
        "zeroshot/medium",
        "stats/nano",
        "generate/nano",
        "e2e/small",
        "sweep/small/sparsegpt-50%,magnitude-2:4,adaprune-50%",
        "sweep/small", // dense-only sweep
        "serve/nano/sparsegpt-50%",
        "serve/small/magnitude-2:4",
        "serve/medium/sparsegpt-2:4+4bit",
        "serve/nano/sparsegpt-50%,kv=off",
        "serve/small/sparsegpt-2:4,chunk=8",
        "serve/small/sparsegpt-50%,cache-mb=16",
        "serve/medium/sparsegpt-50%,kv=off,chunk=1,cache-mb=4,prefill=256",
        "serve/nano/sparsegpt-50%,fmt=qcsr:4",
        "serve/nano/sparsegpt-50%,fmt=qcsr:4,g=128",
        "serve/small/sparsegpt-2:4,fmt=qnm:8",
        "serve/small/sparsegpt-2:4+4bit,fmt=qnm:4,g=64",
        "serve/nano/sparsegpt-50%,fmt=qdense:3",
        "serve/medium/sparsegpt-50%,kv=off,chunk=1,cache-mb=4,prefill=256,fmt=qcsr:4,g=32",
        "serve/nano/sparsegpt-50%,fmt=csr",
        "serve/nano/sparsegpt-50%,net=127.0.0.1:7070",
        "serve/nano/sparsegpt-50%,net=0.0.0.0:0",
        "serve/nano/sparsegpt-50%,cancel=1@3",
        "serve/small/sparsegpt-2:4,cancel=0@2+3@7",
        "serve/medium/sparsegpt-50%,kv=off,fmt=qcsr:4,net=127.0.0.1:9000,cancel=2@5",
        "serve/nano/sparsegpt-50%,workers=4",
        "serve/medium/sparsegpt-50%,kv=off,chunk=1,workers=2,fmt=qcsr:4",
        "serve/nano/sparsegpt-50%,fmt=csr:perm",
        "serve/nano/sparsegpt-50%,snap=4",
        "serve/nano/sparsegpt-50%,clock=mock",
        "serve/medium/sparsegpt-50%,kv=off,net=127.0.0.1:9000,cancel=2@5,snap=8,clock=mock",
    ] {
        let spec = JobSpec::parse(label).unwrap_or_else(|e| panic!("{label}: {e:#}"));
        assert_eq!(spec.label(), label, "label round trip for {label}");
        assert_eq!(JobSpec::parse(&spec.label()).unwrap(), spec, "parse round trip");
    }
}

#[test]
fn job_spec_defaults_match_cli() {
    let JobSpec::Prune(p) = JobSpec::parse("prune/nano/sparsegpt-50%").unwrap() else {
        panic!("wrong kind");
    };
    assert_eq!(p.config, "nano");
    assert_eq!(p.damp, 0.01);
    assert_eq!(p.calib, 128);
    assert!(!p.save);
    let JobSpec::Sweep(s) = JobSpec::parse("sweep/small/sparsegpt-50%,magnitude-50%").unwrap()
    else {
        panic!("wrong kind");
    };
    assert_eq!(s.variants.len(), 2);
    assert!(!s.include_dense);
    assert_eq!(s.zeroshot_items, 0);
}

#[test]
fn job_spec_rejects_malformed() {
    for bad in [
        "",
        "wat/nano",
        "train",
        "train/",
        "train/nano/extra",
        "prune/nano",
        "prune/nano/bogus-50%",
        "sweep/nano/sparsegpt-50%,bogus",
        "serve/",
        "serve/nano/bogus-50%",
        "serve/nano/sparsegpt-50%,kv=sometimes",
        "serve/nano/sparsegpt-50%,chunk=",
        "serve/nano/sparsegpt-50%,budget=4",
        "serve/nano/sparsegpt-50%,fmt=bogus",
        "serve/nano/sparsegpt-50%,fmt=qcsr:1",
        "serve/nano/sparsegpt-50%,fmt=qcsr:9",
        "serve/nano/sparsegpt-50%,g=128",
        "serve/nano/sparsegpt-50%,fmt=dense,g=8",
        "serve/nano/sparsegpt-50%,net=",
        "serve/nano/sparsegpt-50%,cancel=1",
        "serve/nano/sparsegpt-50%,cancel=x@3",
        "serve/nano/sparsegpt-50%,cancel=1@",
        "serve/nano/sparsegpt-50%,workers=",
        "serve/nano/sparsegpt-50%,workers=x",
        "serve/nano/sparsegpt-50%,snap=",
        "serve/nano/sparsegpt-50%,snap=x",
        "serve/nano/sparsegpt-50%,clock=",
        "serve/nano/sparsegpt-50%,clock=maybe",
        "gen-data/nano",
    ] {
        assert!(JobSpec::parse(bad).is_err(), "should reject {bad:?}");
    }
}

#[test]
fn serve_quant_format_labels_map_to_fields() {
    use sparsegpt::sparse::PackFormat;
    let JobSpec::Serve(s) =
        JobSpec::parse("serve/nano/sparsegpt-50%,fmt=qcsr:4,g=128").unwrap()
    else {
        panic!("wrong kind");
    };
    assert_eq!(s.format, PackFormat::QCsr { bits: 4, group: 128 });
    let JobSpec::Serve(s) = JobSpec::parse("serve/small/sparsegpt-2:4,fmt=qnm:8").unwrap() else {
        panic!("wrong kind");
    };
    assert_eq!(s.format, PackFormat::QNm { bits: 8, group: 0 });
    // defaults: no fmt knob means Auto (f32, never quantized)
    let JobSpec::Serve(d) = JobSpec::parse("serve/nano/sparsegpt-50%").unwrap() else {
        panic!("wrong kind");
    };
    assert_eq!(d.format, PackFormat::Auto);
}

#[test]
fn serve_net_and_cancel_knob_labels_map_to_fields() {
    let JobSpec::Serve(s) =
        JobSpec::parse("serve/nano/sparsegpt-50%,net=127.0.0.1:7070,cancel=1@3+0@5").unwrap()
    else {
        panic!("wrong kind");
    };
    assert_eq!(s.listen.as_deref(), Some("127.0.0.1:7070"));
    assert_eq!(s.cancel, vec![(1, 3), (0, 5)]);
    // defaults: no net/cancel knobs means synthetic workload, no cancels
    let JobSpec::Serve(d) = JobSpec::parse("serve/nano/sparsegpt-50%").unwrap() else {
        panic!("wrong kind");
    };
    assert!(d.listen.is_none());
    assert!(d.cancel.is_empty());
    assert!(d.addr_file.is_none());
}

#[test]
fn serve_telemetry_knob_labels_map_to_fields() {
    let JobSpec::Serve(s) =
        JobSpec::parse("serve/nano/sparsegpt-50%,snap=4,clock=mock").unwrap()
    else {
        panic!("wrong kind");
    };
    assert_eq!(s.snap_every, 4);
    assert!(s.mock_clock);
    // the metrics file is a CLI-only knob: never encoded in the label
    assert!(s.metrics_file.is_none());
    // clock=real parses (explicit default) but canonicalizes away
    let JobSpec::Serve(s) = JobSpec::parse("serve/nano/sparsegpt-50%,clock=real").unwrap() else {
        panic!("wrong kind");
    };
    assert!(!s.mock_clock);
    assert_eq!(JobSpec::Serve(s).label(), "serve/nano/sparsegpt-50%");
    // defaults: no periodic snapshots, real clock
    let JobSpec::Serve(d) = JobSpec::parse("serve/nano/sparsegpt-50%").unwrap() else {
        panic!("wrong kind");
    };
    assert_eq!(d.snap_every, 0);
    assert!(!d.mock_clock);
}

#[test]
fn serve_cache_knob_labels_map_to_fields() {
    let JobSpec::Serve(s) =
        JobSpec::parse("serve/nano/sparsegpt-50%,kv=off,chunk=4,cache-mb=8,prefill=64").unwrap()
    else {
        panic!("wrong kind");
    };
    assert!(!s.kv_cache);
    assert_eq!(s.prefill_chunk, 4);
    assert_eq!(s.cache_budget_mb, 8);
    assert_eq!(s.max_prefill_tokens, 64);
    let JobSpec::Serve(s) = JobSpec::parse("serve/nano/sparsegpt-50%,workers=3").unwrap() else {
        panic!("wrong kind");
    };
    assert_eq!(s.workers, 3);
    // defaults: the canonical label of a default spec carries no knob tail
    let JobSpec::Serve(d) = JobSpec::parse("serve/nano/sparsegpt-50%").unwrap() else {
        panic!("wrong kind");
    };
    assert!(d.kv_cache);
    assert_eq!(JobSpec::Serve(d).label(), "serve/nano/sparsegpt-50%");
}
