//! Hand-rolled property tests for the wire codec (seeded [`Rng`], no
//! proptest dependency): arbitrarily generated frames round-trip through
//! encode/parse bit-exactly, [`FrameDecoder`] reassembly is invariant to
//! read boundaries (including splits inside multi-byte UTF-8 and CRLF
//! endings), malformed or mutated input errors but never panics, the
//! [`MAX_FRAME_BYTES`] cap holds under any chunking, and the canonical
//! wire bytes (keys alphabetical, one `\n`-terminated line per frame)
//! stay pinned.
//!
//! [`Rng`]: sparsegpt::util::prng::Rng

use sparsegpt::serve::net::{ClientFrame, FrameDecoder, ServerFrame, MAX_FRAME_BYTES};
use sparsegpt::util::prng::Rng;

/// Largest integer JSON numbers carry exactly (2^53): ids and seeds on
/// the wire are capped here by the protocol.
const MAX_SAFE_INT: u64 = 1 << 53;

/// Alphabet chosen to stress the string escaper and the byte-oriented
/// decoder: quotes, backslashes, control characters, and multi-byte
/// UTF-8 that read boundaries will split mid-character.
const CHARS: [char; 12] = ['a', 'Z', '7', '_', ' ', '"', '\\', '\n', '\t', '{', 'é', '🦀'];

fn arb_string(rng: &mut Rng) -> String {
    (0..rng.below(8)).map(|_| CHARS[rng.below(CHARS.len())]).collect()
}

fn arb_tag(rng: &mut Rng) -> Option<String> {
    if rng.below(2) == 0 {
        None
    } else {
        Some(arb_string(rng))
    }
}

fn arb_id(rng: &mut Rng) -> u64 {
    rng.next_u64() & (MAX_SAFE_INT - 1)
}

/// Dyadic rationals: exactly representable in f64 and in their decimal
/// printing, so round-trip equality is meaningful.
fn arb_f64(rng: &mut Rng) -> f64 {
    rng.below(1 << 20) as f64 / 1024.0
}

/// Any i32, including negatives (the protocol does not restrict tokens).
fn arb_token(rng: &mut Rng) -> i32 {
    rng.next_u64() as u32 as i32
}

fn arb_client_frame(rng: &mut Rng) -> ClientFrame {
    match rng.below(4) {
        0 | 1 => ClientFrame::Request {
            tag: arb_tag(rng),
            prompt: (0..rng.below(6)).map(|_| arb_token(rng)).collect(),
            max_new_tokens: 1 + rng.below(4096),
            seed: arb_id(rng),
            model: if rng.below(2) == 0 { None } else { Some(arb_string(rng)) },
        },
        2 => ClientFrame::Cancel { id: arb_id(rng) },
        _ => ClientFrame::Shutdown,
    }
}

fn arb_server_frame(rng: &mut Rng) -> ServerFrame {
    match rng.below(7) {
        0 => ServerFrame::Hello { config: arb_string(rng), vocab: rng.below(1 << 20) },
        1 => ServerFrame::Accepted { id: arb_id(rng), tag: arb_tag(rng) },
        2 => ServerFrame::Token {
            id: arb_id(rng),
            index: rng.below(1 << 20),
            token: arb_token(rng),
        },
        3 => ServerFrame::Finished {
            id: arb_id(rng),
            tokens: rng.below(1 << 20),
            ttft_ms: arb_f64(rng),
            gap_p50_ms: arb_f64(rng),
            gap_p95_ms: arb_f64(rng),
        },
        4 => ServerFrame::Rejected {
            id: arb_id(rng),
            tag: arb_tag(rng),
            queue: rng.below(128),
            cap: rng.below(128),
            message: arb_string(rng),
        },
        5 => ServerFrame::Cancelled { id: arb_id(rng), tokens: rng.below(1 << 20) },
        _ => ServerFrame::Error { message: arb_string(rng) },
    }
}

#[test]
fn arbitrary_frames_round_trip_exactly() {
    let mut rng = Rng::new(0xC0DEC);
    for i in 0..500 {
        let c = arb_client_frame(&mut rng);
        let line = c.encode();
        assert!(
            line.ends_with('\n') && !line[..line.len() - 1].contains('\n'),
            "client frame {i}: embedded newline escaped the framing"
        );
        assert_eq!(ClientFrame::parse(line.trim_end()).unwrap(), c, "client frame {i}");
        let s = arb_server_frame(&mut rng);
        let line = s.encode();
        assert!(
            line.ends_with('\n') && !line[..line.len() - 1].contains('\n'),
            "server frame {i}: embedded newline escaped the framing"
        );
        assert_eq!(ServerFrame::parse(line.trim_end()).unwrap(), s, "server frame {i}");
    }
}

#[test]
fn decoder_is_invariant_to_read_boundaries() {
    let mut rng = Rng::new(0xB0B);
    for trial in 0..40 {
        // one wire session: mixed frames, some CRLF-terminated, blank
        // keep-alive lines interleaved (all tolerated by the decoder)
        let mut frames = Vec::new();
        let mut wire = String::new();
        for _ in 0..1 + rng.below(30) {
            let f = arb_server_frame(&mut rng);
            let enc = f.encode();
            if rng.below(4) == 0 {
                wire.push_str(enc.trim_end());
                wire.push_str("\r\n");
            } else {
                wire.push_str(&enc);
            }
            if rng.below(5) == 0 {
                wire.push('\n');
            }
            frames.push(f);
        }
        // chunk at arbitrary byte boundaries — often mid-UTF-8-character
        let bytes = wire.as_bytes();
        let mut dec = FrameDecoder::new();
        let mut lines = Vec::new();
        let mut i = 0;
        while i < bytes.len() {
            let j = (i + 1 + rng.below(9)).min(bytes.len());
            let chunk = &bytes[i..j];
            lines.extend(dec.push(chunk).unwrap_or_else(|e| panic!("trial {trial}: {e:#}")));
            i = j;
        }
        assert_eq!(dec.pending_bytes(), 0, "trial {trial}: bytes left behind");
        let got: Vec<ServerFrame> = lines.iter().map(|l| ServerFrame::parse(l).unwrap()).collect();
        assert_eq!(got, frames, "trial {trial}: reassembly changed the frames");
    }
}

#[test]
fn mutated_frames_error_or_parse_but_never_panic() {
    // the property under mutation is purely "no panic": a flipped byte may
    // happen to still be a valid frame, and that is fine
    let mut rng = Rng::new(0xFADE);
    for _ in 0..400 {
        let line = if rng.below(2) == 0 {
            arb_client_frame(&mut rng).encode()
        } else {
            arb_server_frame(&mut rng).encode()
        };
        let mut bytes = line.trim_end().as_bytes().to_vec();
        match rng.below(3) {
            0 => {
                let i = rng.below(bytes.len());
                bytes[i] = rng.next_u64() as u8;
            }
            1 => {
                let keep = rng.below(bytes.len() + 1);
                bytes.truncate(keep);
            }
            _ => {
                let i = rng.below(bytes.len() + 1);
                bytes.insert(i, rng.next_u64() as u8);
            }
        }
        let s = String::from_utf8_lossy(&bytes).into_owned();
        let _ = ClientFrame::parse(&s);
        let _ = ServerFrame::parse(&s);
        // and through the decoder, raw (possibly invalid UTF-8) bytes
        let mut dec = FrameDecoder::new();
        bytes.push(b'\n');
        if let Ok(lines) = dec.push(&bytes) {
            for l in lines {
                let _ = ClientFrame::parse(&l);
                let _ = ServerFrame::parse(&l);
            }
        }
    }
}

#[test]
fn integers_past_2_53_are_rejected_not_rounded() {
    for bad in [
        r#"{"reason":"cancel","id":18446744073709551615}"#,
        r#"{"reason":"cancel","id":1e300}"#,
        r#"{"reason":"request","prompt":[],"max_new_tokens":1,"seed":1e60}"#,
        r#"{"reason":"token","id":0,"index":1e20,"token":0}"#,
    ] {
        assert!(ClientFrame::parse(bad).is_err() || ServerFrame::parse(bad).is_err(), "{bad}");
    }
    // u64::MAX is rejected on both sides, not rounded into range
    let huge = r#"{"reason":"cancel","id":18446744073709551615}"#;
    assert!(ClientFrame::parse(huge).is_err());
    // the cap itself is representable and accepted
    let at_cap = format!(r#"{{"reason":"cancel","id":{MAX_SAFE_INT}}}"#);
    assert_eq!(ClientFrame::parse(&at_cap).unwrap(), ClientFrame::Cancel { id: MAX_SAFE_INT });
}

#[test]
fn frame_size_cap_holds_under_any_chunking() {
    let mut rng = Rng::new(0xCAFE);
    let bytes = vec![b'x'; MAX_FRAME_BYTES + 2];
    let mut dec = FrameDecoder::new();
    let mut i = 0;
    let mut erred = false;
    while i < bytes.len() {
        let j = (i + 1 + rng.below(64 * 1024)).min(bytes.len());
        if dec.push(&bytes[i..j]).is_err() {
            erred = true;
            break;
        }
        i = j;
    }
    assert!(erred, "an unbounded line crossed the cap without erroring");
    // the same volume with newlines interleaved streams through fine
    let mut dec = FrameDecoder::new();
    let mut total = 0;
    for _ in 0..8 {
        let mut chunk = vec![b'y'; MAX_FRAME_BYTES / 2];
        *chunk.last_mut().unwrap() = b'\n';
        total += dec.push(&chunk).unwrap().len();
    }
    assert_eq!(total, 8);
    assert_eq!(dec.pending_bytes(), 0);
}

#[test]
fn canonical_wire_bytes_are_pinned() {
    // keys serialize alphabetically (BTreeMap), one line per frame — the
    // bytes a foreign-language client must produce and accept
    let req = ClientFrame::Request {
        tag: Some("a".into()),
        prompt: vec![1, 2, 3],
        max_new_tokens: 8,
        seed: 7,
        model: None,
    };
    assert_eq!(
        req.encode(),
        "{\"max_new_tokens\":8,\"prompt\":[1,2,3],\"reason\":\"request\",\"seed\":7,\"tag\":\"a\"}\n"
    );
    let routed = ClientFrame::Request {
        tag: None,
        prompt: vec![1],
        max_new_tokens: 2,
        seed: 0,
        model: Some("q4".into()),
    };
    assert_eq!(
        routed.encode(),
        "{\"max_new_tokens\":2,\"model\":\"q4\",\"prompt\":[1],\"reason\":\"request\",\"seed\":0}\n"
    );
    let tok = ServerFrame::Token { id: 4, index: 0, token: 17 };
    assert_eq!(tok.encode(), "{\"id\":4,\"index\":0,\"reason\":\"token\",\"token\":17}\n");
    let fin = ServerFrame::Finished {
        id: 4,
        tokens: 2,
        ttft_ms: 1.5,
        gap_p50_ms: 0.25,
        gap_p95_ms: 0.75,
    };
    assert_eq!(
        fin.encode(),
        "{\"gap_p50_ms\":0.25,\"gap_p95_ms\":0.75,\"id\":4,\"reason\":\"finished\",\"tokens\":2,\"ttft_ms\":1.5}\n"
    );
}
