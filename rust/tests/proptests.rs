//! Hand-rolled property tests (proptest is unavailable offline): randomized
//! inputs over many seeds, asserting the coordinator/solver invariants that
//! the paper's method guarantees by construction.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering::Relaxed};

use sparsegpt::coordinator::SkipSpec;
use sparsegpt::data::corpus::{gen_corpus, CorpusStyle, Lexicon};
use sparsegpt::data::Tokenizer;
use sparsegpt::model::init::init_params;
use sparsegpt::model::layout::{LinearKind, PRUNABLE_KINDS};
use sparsegpt::model::{ModelCfg, SparseStore};
use sparsegpt::obs::{Counter, Histogram};
use sparsegpt::serve::{
    EngineOptions, KvCache, SchedulerPolicy, ServeEngine, ServeRequest, SparseModel,
};
use sparsegpt::solver::exact::exact_reconstruction;
use sparsegpt::solver::hessian::{dampened_hinv_chol_f64, layer_sq_error};
use sparsegpt::solver::magnitude::{magnitude_prune, magnitude_prune_nm};
use sparsegpt::solver::quant::QuantGrid;
use sparsegpt::solver::sparsegpt_ref::{ref_sparsegpt, Pattern};
use sparsegpt::sparse::{
    dense_layer, CsrMatrix, NmMatrix, PackFormat, PackPolicy, PackedMatrix, WorkerPool,
};
use sparsegpt::tensor::linalg::{dampen, Mat};
use sparsegpt::tensor::Tensor;
use sparsegpt::util::prng::Rng;

const TRIALS: u64 = 12;

fn problem(rng: &mut Rng, r: usize, c: usize) -> (Tensor, Tensor, Tensor) {
    let w = Tensor::new(vec![r, c], (0..r * c).map(|_| rng.normal_f32()).collect());
    let n = 2 * c;
    let x = Tensor::new(vec![n, c], (0..n * c).map(|_| rng.normal_f32()).collect());
    let h = x.transpose2().matmul(&x);
    let hc = dampened_hinv_chol_f64(&h, 0.01).unwrap();
    (w, h, hc)
}

fn rand_shape(rng: &mut Rng) -> (usize, usize) {
    let rows = [8, 16, 24, 48, 64];
    let cols = [16, 32, 64, 96];
    (rows[rng.below(rows.len())], cols[rng.below(cols.len())])
}

/// Property: the solver prunes exactly round(p * numel) weights (to zero).
#[test]
fn prop_solver_density_exact() {
    for seed in 0..TRIALS {
        let mut rng = Rng::new(seed);
        let (r, c) = rand_shape(&mut rng);
        let p = 0.1 + 0.8 * rng.f64();
        let (w, _h, hc) = problem(&mut rng, r, c);
        let (wh, mask) = ref_sparsegpt(&w, &hc, Pattern::Unstructured(p), 0, 128);
        let pruned = mask.data().iter().filter(|&&m| m == 0.0).count();
        // selection happens per Bs-column block; sum the exact per-block counts
        let bs = 128usize.min(c);
        let mut expect = 0usize;
        let mut i = 0;
        while i < c {
            let width = bs.min(c - i);
            expect += (p * (r * width) as f64).round() as usize;
            i += width;
        }
        assert_eq!(pruned, expect, "seed {seed} shape ({r},{c}) p {p}");
        for (x, m) in wh.data().iter().zip(mask.data()) {
            if *m == 0.0 {
                assert_eq!(*x, 0.0);
            }
        }
    }
}

/// Property: every n:m group has exactly n zeros, for all supported patterns.
#[test]
fn prop_nm_constraint() {
    for seed in 0..TRIALS {
        let mut rng = Rng::new(seed ^ 0xA0);
        let r = 16 + 8 * rng.below(4);
        let c = 32 + 32 * rng.below(3);
        let (w, _h, hc) = problem(&mut rng, r, c);
        for (n, m) in [(2usize, 4usize), (4, 8)] {
            let (_, mask) = ref_sparsegpt(&w, &hc, Pattern::NM(n, m), 0, 128);
            for row in 0..r {
                for g in (0..c).step_by(m) {
                    let kept: f32 = (g..g + m).map(|j| mask.at2(row, j)).sum();
                    assert_eq!(kept as usize, m - n, "seed {seed} row {row} g {g}");
                }
            }
        }
    }
}

/// Property: SparseGPT's reconstruction error never exceeds mask-and-zero
/// on its own mask, and exact reconstruction never exceeds SparseGPT.
#[test]
fn prop_error_ordering() {
    for seed in 0..TRIALS {
        let mut rng = Rng::new(seed ^ 0xB0);
        let (r, c) = (16, 48);
        let (w, h, hc) = problem(&mut rng, r, c);
        let (wh, mask) = ref_sparsegpt(&w, &hc, Pattern::Unstructured(0.5), 0, 128);
        let hd_m = dampen(&Mat::from_f32(c, h.data()), 0.01);
        let hd = Tensor::new(vec![c, c], hd_m.to_f32());
        let we = exact_reconstruction(&w, &mask, &hd, None).unwrap();
        let wz: Vec<f32> = w.data().iter().zip(mask.data()).map(|(x, m)| x * m).collect();
        let wz = Tensor::new(vec![r, c], wz);
        let (e_exact, e_sgpt, e_zero) = (
            layer_sq_error(&w, &we, &hd),
            layer_sq_error(&w, &wh, &hd),
            layer_sq_error(&w, &wz, &hd),
        );
        assert!(e_exact <= e_sgpt * (1.0 + 1e-6), "seed {seed}: {e_exact} > {e_sgpt}");
        assert!(e_sgpt <= e_zero * (1.0 + 1e-6), "seed {seed}: {e_sgpt} > {e_zero}");
    }
}

/// Property: joint quantization keeps every surviving weight on its row grid.
#[test]
fn prop_joint_quant_on_grid() {
    for seed in 0..TRIALS {
        let mut rng = Rng::new(seed ^ 0xC0);
        let (r, c) = (12, 32);
        let (w, _h, hc) = problem(&mut rng, r, c);
        let bits = [2u32, 3, 4][rng.below(3)];
        let levels = (1u32 << bits) - 1;
        let (wh, mask) = ref_sparsegpt(&w, &hc, Pattern::Unstructured(0.4), levels, 128);
        let grid = QuantGrid::from_weights(&w, levels);
        for row in 0..r {
            for col in 0..c {
                if mask.at2(row, col) == 1.0 {
                    let v = wh.at2(row, col);
                    assert!(
                        (v - grid.quantize_one(row, v)).abs() < 1e-5,
                        "seed {seed} off-grid {v}"
                    );
                }
            }
        }
    }
}

/// Property: sparse engines agree with the dense GEMM on random masks.
#[test]
fn prop_sparse_engines_match_dense() {
    for seed in 0..TRIALS {
        let mut rng = Rng::new(seed ^ 0xD0);
        let (o, k, t) = (8 + 4 * rng.below(8), 16 + 16 * rng.below(4), 1 + rng.below(9));
        let w = Tensor::new(vec![o, k], (0..o * k).map(|_| rng.normal_f32()).collect());
        let x = Tensor::new(vec![t, k], (0..t * k).map(|_| rng.normal_f32()).collect());
        let p = rng.f64() * 0.9;
        let (wp, _) = magnitude_prune(&w, p);
        let yd = dense_layer(&x, &wp);
        let yc = CsrMatrix::from_dense(&wp).unwrap().layer(&x);
        for (a, b) in yd.data().iter().zip(yc.data()) {
            assert!((a - b).abs() < 1e-3, "csr mismatch seed {seed}");
        }
        let (w24, _) = magnitude_prune_nm(&w, 2, 4);
        let ynm = NmMatrix::from_dense(&w24, 2, 4).unwrap().layer(&x);
        let yd24 = dense_layer(&x, &w24);
        for (a, b) in yd24.data().iter().zip(ynm.data()) {
            assert!((a - b).abs() < 1e-3, "nm mismatch seed {seed}");
        }
    }
}

/// Property: the CSR and n:m sparse kernels (both the vectorized and the
/// gather variants) agree with the dense GEMM and the blocked `matmul` on
/// ARBITRARY masks — random Bernoulli patterns of every density and
/// randomly-chosen n:m survivors, not just magnitude-selected ones.
#[test]
fn prop_sparse_kernels_match_dense_on_arbitrary_masks() {
    for seed in 0..TRIALS {
        let mut rng = Rng::new(seed ^ 0x1A0);
        let o = 4 + 4 * rng.below(10);
        let k = 8 * (1 + rng.below(6)); // divisible by 4 and 8 for n:m
        let t = 1 + rng.below(10);
        let density = rng.f64();
        let mut w = Tensor::new(vec![o, k], (0..o * k).map(|_| rng.normal_f32()).collect());
        for x in w.data_mut() {
            if rng.f64() >= density {
                *x = 0.0; // arbitrary unstructured mask (incl. empty rows)
            }
        }
        let x = Tensor::new(vec![t, k], (0..t * k).map(|_| rng.normal_f32()).collect());
        let yd = dense_layer(&x, &w);
        let ymm = x.matmul(&w.transpose2());
        let csr = CsrMatrix::from_dense(&w).unwrap();
        for (label, y) in [("csr", csr.layer(&x)), ("csr-gather", csr.layer_gather(&x))] {
            for ((a, b), c) in y.data().iter().zip(yd.data()).zip(ymm.data()) {
                assert!((a - b).abs() < 1e-3, "{label} vs dense, seed {seed}");
                assert!((a - c).abs() < 1e-3, "{label} vs matmul, seed {seed}");
            }
        }
        // n:m with randomly chosen survivors per group (not magnitude)
        for (n, m) in [(2usize, 4usize), (4, 8)] {
            let mut wnm = w.clone();
            for r in 0..o {
                let row = wnm.row_mut(r);
                for g in (0..k).step_by(m) {
                    let mut idx: Vec<usize> = (0..m).collect();
                    rng.shuffle(&mut idx);
                    for &j in &idx[n..] {
                        row[g + j] = 0.0; // keep exactly n random slots
                    }
                }
            }
            let ydn = dense_layer(&x, &wnm);
            let nm = NmMatrix::from_dense(&wnm, n, m).unwrap();
            for (label, y) in [("nm", nm.layer(&x)), ("nm-gather", nm.layer_gather(&x))] {
                for (a, b) in y.data().iter().zip(ydn.data()) {
                    assert!((a - b).abs() < 1e-3, "{label} {n}:{m}, seed {seed}");
                }
            }
        }
    }
}

/// Token counts straddling the tile boundary (t_n ≡ -1, 0, +1 mod
/// TOKEN_TILE = 256), with enough output columns that t_n * o_n clears
/// MIN_PARALLEL_OUT and the parallel tile driver actually engages.
const TILE_EDGE_SHAPES: [(usize, usize); 3] = [(255, 48), (256, 48), (257, 48)];

/// Property: the blocked parallel kernels are BIT-identical to their
/// scalar gather references for every pool size — the worker pool may
/// change which thread computes a token tile, never the sequence of
/// additions any output element sees.
#[test]
fn prop_blocked_kernels_bit_identical_across_pool_sizes() {
    for seed in 0..3u64 {
        let mut rng = Rng::new(seed ^ 0x9A0);
        for (t, o) in TILE_EDGE_SHAPES {
            let k = 32;
            let w = bernoulli_masked(&mut rng, o, k, rng.f64());
            let x = Tensor::new(vec![t, k], (0..t * k).map(|_| rng.normal_f32()).collect());
            let csr = CsrMatrix::from_dense(&w).unwrap();
            let wnm = random_nm_masked(&mut rng, o, k, 2, 4);
            let nm = NmMatrix::from_dense(&wnm, 2, 4).unwrap();
            // scalar references, computed on a single-worker pool
            let (csr_ref, nm_ref, dense_ref) = WorkerPool::new(1)
                .install(|| (csr.layer_gather(&x), nm.layer_gather(&x), dense_layer(&x, &w)));
            for workers in [1usize, 2, 3, 8] {
                let pool = WorkerPool::new(workers);
                let (yc, yn, yd) =
                    pool.install(|| (csr.layer(&x), nm.layer(&x), dense_layer(&x, &w)));
                assert_eq!(yc.data(), csr_ref.data(), "csr seed {seed} t {t} x{workers}");
                assert_eq!(yn.data(), nm_ref.data(), "nm seed {seed} t {t} x{workers}");
                assert_eq!(yd.data(), dense_ref.data(), "dense seed {seed} t {t} x{workers}");
            }
        }
    }
}

/// Property: the row-permuted CSR layout is numerically invisible —
/// to_dense, the blocked kernel and the gather kernel are all
/// BIT-identical to the unpermuted layout on arbitrary masks.
#[test]
fn prop_permuted_csr_bit_identical_to_unpermuted() {
    for seed in 0..TRIALS {
        let mut rng = Rng::new(seed ^ 0xAA0);
        let o = 4 + 4 * rng.below(10);
        let k = 8 * (1 + rng.below(6));
        let t = 1 + rng.below(10);
        let w = bernoulli_masked(&mut rng, o, k, rng.f64());
        let x = Tensor::new(vec![t, k], (0..t * k).map(|_| rng.normal_f32()).collect());
        let plain = CsrMatrix::from_dense(&w).unwrap();
        let perm = CsrMatrix::from_dense_permuted(&w).unwrap();
        assert_eq!(perm.to_dense().data(), w.data(), "to_dense seed {seed}");
        assert_eq!(perm.layer(&x).data(), plain.layer(&x).data(), "layer seed {seed}");
        assert_eq!(
            perm.layer_gather(&x).data(),
            plain.layer_gather(&x).data(),
            "gather seed {seed}"
        );
    }
}

/// Regression: two engines in one process can decode on DIFFERENT worker
/// counts (the old process-wide OnceLock cached whatever count the first
/// kernel call saw, forever), and the count never changes what anything
/// decodes.
#[test]
fn prop_engines_with_different_worker_counts_agree() {
    let cfg = prop_cfg("prop-workers");
    let fp = init_params(&cfg, 0);
    let model = SparseModel::from_params(&fp, &PackPolicy::default()).unwrap();
    let reqs = || -> Vec<(usize, ServeRequest)> {
        (0..3)
            .map(|i| {
                let r = ServeRequest {
                    id: i as u64,
                    prompt: vec![1, 2, 3],
                    max_new_tokens: 6,
                    seed: i as u64,
                    model: None,
                };
                (0, r)
            })
            .collect()
    };
    // both engines alive at once, each sized differently
    let opts = |workers: usize| EngineOptions {
        temperature: 0.0,
        top_k: 0,
        workers,
        ..EngineOptions::default()
    };
    let e1 = ServeEngine::new(&model, opts(1));
    let e3 = ServeEngine::new(&model, opts(3));
    assert_eq!((e1.workers(), e3.workers()), (1, 3), "pool sizes must be per-engine");
    let streams = |e: &ServeEngine| -> Vec<(u64, Vec<i32>)> {
        let mut out: Vec<(u64, Vec<i32>)> = e
            .run(reqs(), &mut |_| {})
            .unwrap()
            .finished
            .iter()
            .map(|f| (f.id, f.tokens.clone()))
            .collect();
        out.sort_by_key(|(id, _)| *id);
        out
    };
    let (a, b) = (streams(&e1), streams(&e3));
    assert!(a.iter().any(|(_, t)| !t.is_empty()), "workload produced no tokens");
    assert_eq!(a, b, "worker count changed decode output");
}

/// Build an arbitrary Bernoulli-masked matrix (any density, empty rows ok).
fn bernoulli_masked(rng: &mut Rng, o: usize, k: usize, density: f64) -> Tensor {
    let mut w = Tensor::new(vec![o, k], (0..o * k).map(|_| rng.normal_f32()).collect());
    for x in w.data_mut() {
        if rng.f64() >= density {
            *x = 0.0;
        }
    }
    w
}

/// Build an arbitrary n:m-masked matrix (random survivors, not magnitude).
fn random_nm_masked(rng: &mut Rng, o: usize, k: usize, n: usize, m: usize) -> Tensor {
    let mut w = Tensor::new(vec![o, k], (0..o * k).map(|_| rng.normal_f32()).collect());
    for r in 0..o {
        let row = w.row_mut(r);
        for g in (0..k).step_by(m) {
            let mut idx: Vec<usize> = (0..m).collect();
            rng.shuffle(&mut idx);
            for &j in &idx[n..] {
                row[g + j] = 0.0;
            }
        }
    }
    w
}

/// Property: pack -> bytes -> unpack is bit-exact for CSR and n:m packed
/// matrices on arbitrary Bernoulli / random-survivor n:m masks.
#[test]
fn prop_pack_bytes_roundtrip_bit_exact() {
    for seed in 0..TRIALS {
        let mut rng = Rng::new(seed ^ 0x2A0);
        let o = 4 + 4 * rng.below(10);
        let k = 8 * (1 + rng.below(6));
        let w = bernoulli_masked(&mut rng, o, k, rng.f64());
        let p = PackedMatrix::pack(&w, &PackPolicy::with_format(PackFormat::Csr)).unwrap();
        let mut buf = Vec::new();
        p.write_bytes(&mut buf);
        let (q, used) = PackedMatrix::read_bytes(&buf).unwrap();
        assert_eq!(used, buf.len(), "seed {seed}");
        assert_eq!(q.to_dense().data(), w.data(), "csr roundtrip seed {seed}");
        for (n, m) in [(2usize, 4usize), (4, 8)] {
            let wnm = random_nm_masked(&mut rng, o, k, n, m);
            let p = PackedMatrix::pack(&wnm, &PackPolicy::with_format(PackFormat::Nm(n, m)))
                .unwrap();
            let mut buf = Vec::new();
            p.write_bytes(&mut buf);
            let (q, used) = PackedMatrix::read_bytes(&buf).unwrap();
            assert_eq!(used, buf.len(), "seed {seed}");
            assert_eq!(q.to_dense().data(), wnm.data(), "{n}:{m} roundtrip seed {seed}");
        }
    }
}

/// Property: quantized pack -> bytes -> unpack is bit-exact (codes, grid
/// and dequantized values) for arbitrary Bernoulli / random-survivor n:m
/// masks across bit widths and grid groupings.
#[test]
fn prop_quantized_pack_bytes_roundtrip_bit_exact() {
    for seed in 0..TRIALS {
        let mut rng = Rng::new(seed ^ 0x7A0);
        let o = 4 + 4 * rng.below(8);
        let k = 8 * (1 + rng.below(5));
        let bits = [2u8, 3, 4, 5, 8][rng.below(5)];
        let group = [0usize, 4, 8][rng.below(3)];
        let w = bernoulli_masked(&mut rng, o, k, rng.f64());
        let wnm = random_nm_masked(&mut rng, o, k, 2, 4);
        let cases = [
            (PackFormat::QDense { bits, group }, &w),
            (PackFormat::QCsr { bits, group }, &w),
            (PackFormat::QNm { bits, group }, &wnm),
        ];
        for (fmt, src) in cases {
            let p = PackedMatrix::pack(src, &PackPolicy::with_format(fmt)).unwrap();
            let mut buf = Vec::new();
            p.write_bytes(&mut buf);
            let (q, used) = PackedMatrix::read_bytes(&buf).unwrap();
            assert_eq!(used, buf.len(), "{} seed {seed}", fmt.label());
            assert_eq!(q.format_label(), p.format_label());
            assert_eq!(q.nnz(), p.nnz(), "{} seed {seed}", fmt.label());
            assert_eq!(q.quant_meta(), p.quant_meta(), "{} seed {seed}", fmt.label());
            assert_eq!(
                q.to_dense().data(),
                p.to_dense().data(),
                "{} seed {seed}",
                fmt.label()
            );
            // structural zeros survive even when the grid lacks a zero point
            for (orig, got) in src.data().iter().zip(q.to_dense().data()) {
                if *orig == 0.0 {
                    assert_eq!(*got, 0.0, "{} seed {seed}", fmt.label());
                }
            }
        }
    }
}

fn prop_cfg(name: &str) -> ModelCfg {
    ModelCfg::from_dims(name, 8, 2, 2, 1, 1, 13, 6)
}

/// Mask every prunable linear of a fresh model with an arbitrary pattern.
fn masked_params(
    rng: &mut Rng,
    cfg: &ModelCfg,
    mask: impl Fn(&mut Rng, usize, usize) -> Tensor,
) -> sparsegpt::model::FlatParams {
    let mut fp = init_params(cfg, rng.next_u64());
    for layer in 0..cfg.layers {
        for kind in PRUNABLE_KINDS {
            let (r, c) = kind.shape(cfg);
            fp.set_linear(kind, layer, &mask(rng, r, c)).unwrap();
        }
    }
    fp
}

/// Property: a packed checkpoint written to disk and read back unpacks to
/// the exact flat parameter vector it was packed from.
#[test]
fn prop_sparse_store_file_roundtrip_bit_exact() {
    let cfg = prop_cfg("prop-store");
    let dir = std::env::temp_dir().join(format!("sgpt_prop_store_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    for seed in 0..TRIALS {
        let mut rng = Rng::new(seed ^ 0x3A0);
        let density = rng.f64();
        let fp = if seed % 2 == 0 {
            masked_params(&mut rng, &cfg, |rng, r, c| bernoulli_masked(rng, r, c, density))
        } else {
            masked_params(&mut rng, &cfg, |rng, r, c| random_nm_masked(rng, r, c, 2, 4))
        };
        let store = SparseStore::pack(&fp, &PackPolicy::default(), "prop").unwrap();
        let path = dir.join(format!("s{seed}.spkt"));
        store.save(&path).unwrap();
        let back = SparseStore::load(&path).unwrap();
        assert_eq!(back.unpack(&cfg).unwrap().data, fp.data, "seed {seed}");
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Property: a quantized `.spkt` v2 file round-trips bit-exactly — the
/// dequantized weights, per-entry quant metadata (bits/group), and
/// effective-bits accounting all survive save/load on arbitrary masks.
#[test]
fn prop_spkt_v2_file_roundtrip_preserves_quant_metadata() {
    let cfg = prop_cfg("prop-qstore");
    let dir = std::env::temp_dir().join(format!("sgpt_prop_qstore_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    for seed in 0..TRIALS {
        let mut rng = Rng::new(seed ^ 0x8A0);
        let density = rng.f64();
        let fp = if seed % 2 == 0 {
            masked_params(&mut rng, &cfg, |rng, r, c| bernoulli_masked(rng, r, c, density))
        } else {
            masked_params(&mut rng, &cfg, |rng, r, c| random_nm_masked(rng, r, c, 2, 4))
        };
        let bits = [3u8, 4, 8][rng.below(3)];
        let group = [0usize, 4][rng.below(2)];
        let fmt = if seed % 2 == 0 {
            PackFormat::QCsr { bits, group }
        } else {
            PackFormat::QNm { bits, group }
        };
        let store = SparseStore::pack(&fp, &PackPolicy::with_format(fmt), "prop-q").unwrap();
        let path = dir.join(format!("q{seed}.spkt"));
        store.save(&path).unwrap();
        let back = SparseStore::load(&path).unwrap();
        let (a, b) = (back.unpack(&cfg).unwrap(), store.unpack(&cfg).unwrap());
        assert_eq!(a.data, b.data, "seed {seed}");
        assert_eq!(back.effective_bits(), store.effective_bits(), "seed {seed}");
        for (a, b) in store.entries.iter().zip(&back.entries) {
            assert_eq!(a.matrix.format_label(), b.matrix.format_label(), "seed {seed}");
            assert_eq!(a.matrix.quant_meta(), b.matrix.quant_meta(), "seed {seed}");
            assert_eq!(
                a.matrix.quant_meta(),
                Some((bits, if group == 0 { 0u16 } else { group as u16 })),
                "seed {seed}"
            );
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Property: packed decode (CSR / n:m kernels) is element-identical to
/// dense decode of the same pruned parameters — the serving engine's
/// correctness contract, on the banded re-forward path.
#[test]
fn prop_packed_decode_element_identical_to_dense() {
    let cfg = prop_cfg("prop-serve");
    for seed in 0..TRIALS {
        let mut rng = Rng::new(seed ^ 0x4A0);
        let density = rng.f64();
        let fp = if seed % 2 == 0 {
            masked_params(&mut rng, &cfg, |rng, r, c| bernoulli_masked(rng, r, c, density))
        } else {
            masked_params(&mut rng, &cfg, |rng, r, c| random_nm_masked(rng, r, c, 2, 4))
        };
        let dense =
            SparseModel::from_params(&fp, &PackPolicy::with_format(PackFormat::Dense)).unwrap();
        let packed = SparseModel::from_params(&fp, &PackPolicy::default()).unwrap();
        let batch = 1 + rng.below(3);
        let seqs: Vec<Vec<i32>> = (0..batch)
            .map(|_| {
                let len = 1 + rng.below(2 * cfg.seq);
                (0..len).map(|_| rng.below(cfg.vocab) as i32).collect()
            })
            .collect();
        let seqs: Vec<&[i32]> = seqs.iter().map(|s| s.as_slice()).collect();
        let a = dense.forward_logits(&seqs).unwrap();
        let b = packed.forward_logits(&seqs).unwrap();
        assert_eq!(a.data(), b.data(), "seed {seed} ({})", packed.format_summary());
    }
}

/// Property: the KV ring buffer is exact — random append/commit schedules
/// never reorder or corrupt surviving positions, the resident set is
/// always the trailing `min(total, capacity)` positions, and the eviction
/// counts account for every overwritten entry.
#[test]
fn prop_kv_cache_ring_exact() {
    for seed in 0..TRIALS {
        let mut rng = Rng::new(seed ^ 0x5A0);
        let layers = 1 + rng.below(3);
        let d = 1 + rng.below(6);
        let cap = 1 + rng.below(8);
        let mut cache = KvCache::new(layers, d, cap);
        // mirror: every row ever written, by absolute position
        let mut mirror: Vec<Vec<f32>> = Vec::new();
        let mut evicted_total = 0usize;
        while mirror.len() < 4 * cap {
            let n = 1 + rng.below(2 * cap); // commits larger than cap too
            for _ in 0..n {
                let pos = mirror.len();
                let row: Vec<f32> = (0..d).map(|j| (pos * 31 + j) as f32).collect();
                for l in 0..layers {
                    cache.write(l, pos, &row, &row);
                }
                mirror.push(row);
            }
            evicted_total += cache.commit(n);
            let total = mirror.len();
            assert_eq!(cache.next_pos(), total, "seed {seed}");
            assert_eq!(cache.len(), total.min(cap), "seed {seed}");
            assert_eq!(evicted_total, total - cache.len(), "seed {seed}");
            // surviving positions are exactly the trailing window, in order
            for pos in cache.first_pos()..cache.next_pos() {
                for l in 0..layers {
                    assert_eq!(cache.k_row(l, pos), &mirror[pos][..], "seed {seed} pos {pos}");
                    assert_eq!(cache.v_row(l, pos), &mirror[pos][..], "seed {seed} pos {pos}");
                }
            }
        }
    }
}

/// Property: whatever the workload, policy, and cache budget, a drained
/// engine has returned every reserved cache byte — retire frees the cache,
/// and the budget ends at zero with the peak never above the limit's
/// one-request floor.
#[test]
fn prop_retire_returns_cache_budget_to_zero() {
    let cfg = prop_cfg("prop-budget");
    let fp = init_params(&cfg, 0);
    let model = SparseModel::from_params(&fp, &PackPolicy::default()).unwrap();
    let unit = model.cache_bytes();
    for seed in 0..TRIALS {
        let mut rng = Rng::new(seed ^ 0x6A0);
        let n = 1 + rng.below(6);
        let reqs: Vec<(usize, ServeRequest)> = (0..n)
            .map(|i| {
                let plen = 1 + rng.below(2 * cfg.seq);
                (
                    rng.below(3),
                    ServeRequest {
                        id: i as u64,
                        prompt: (0..plen).map(|_| rng.below(cfg.vocab) as i32).collect(),
                        max_new_tokens: 1 + rng.below(8),
                        seed: rng.next_u64(),
                        model: None,
                    },
                )
            })
            .collect();
        let slots = 1 + rng.below(3) as u64;
        let opts = EngineOptions {
            policy: SchedulerPolicy {
                max_batch: 1 + rng.below(4),
                max_wait: rng.below(2),
                queue_cap: 8,
                max_prefill_tokens: [0, cfg.seq][rng.below(2)],
            },
            temperature: 0.0,
            top_k: 0,
            cache_budget_bytes: slots * unit,
            ..EngineOptions::default()
        };
        let out = ServeEngine::new(&model, opts).run(reqs, &mut |_| {}).unwrap();
        assert_eq!(out.finished.len(), n, "seed {seed}: backpressure must still drain");
        assert_eq!(out.cache_bytes_in_use, 0, "seed {seed}: budget not returned");
        assert!(
            out.peak_cache_bytes <= slots.max(1) * unit,
            "seed {seed}: peak {} exceeds budget {}",
            out.peak_cache_bytes,
            slots * unit
        );
    }
}

/// Property: tokenizer round-trips arbitrary byte strings.
#[test]
fn prop_tokenizer_roundtrip() {
    let lex = Lexicon::new(0);
    let text = gen_corpus(&lex, CorpusStyle::C4, 0, 30_000);
    let tok = Tokenizer::train(&text[..20_000]);
    for seed in 0..TRIALS {
        let mut rng = Rng::new(seed ^ 0xE0);
        let start = rng.below(text.len() - 200);
        let mut s: String = text[start..].chars().take(150).collect();
        if rng.f64() < 0.5 {
            s.push_str("\u{00e9}\u{4e2d}!? 123");
        }
        assert_eq!(tok.decode(&tok.encode(&s)), s, "seed {seed}");
    }
}

/// Property: skip policies partition the model consistently — every matrix
/// is pruned by SkipSpec::None, each layer is skipped by exactly one Third,
/// and PrefixFraction is monotone in the fraction.
#[test]
fn prop_skip_policies_consistent() {
    for layers in [3usize, 6, 9, 12, 24] {
        for l in 0..layers {
            for kind in [LinearKind::Wq, LinearKind::Fc1, LinearKind::Fc2] {
                assert!(SkipSpec::None.should_prune(l, kind, layers));
                let skipped_by = (0..3)
                    .filter(|&t| !SkipSpec::Third(t).should_prune(l, kind, layers))
                    .count();
                assert_eq!(skipped_by, 1);
                let mut prev_pruned = true;
                for f in [1.0, 0.75, 0.5, 0.25, 0.0] {
                    let now = SkipSpec::PrefixFraction(f).should_prune(l, kind, layers);
                    assert!(prev_pruned || !now, "prefix monotonicity violated");
                    prev_pruned = now;
                }
            }
        }
    }
}

/// Property: the lock-free metric primitives are exactly counted under
/// concurrency — writer threads hammer one Counter and one Histogram
/// while a reader polls (reads are monotone and `snapshot()`'s bounded
/// retry always terminates under fire), and once the writers join, the
/// totals and per-bucket counts equal the precomputed expectation: no
/// increment is ever lost.
#[test]
fn prop_metrics_concurrent_updates_never_lose_increments() {
    for seed in 0..TRIALS {
        let mut rng = Rng::new(seed ^ 0xB10);
        let writers = 2 + rng.below(3);
        let per_writer = 500 + rng.below(1500);
        // precomputed value streams (shifted for varied bit lengths), so
        // the expected totals and bucket shape are exact
        let mut streams: Vec<Vec<u64>> = Vec::new();
        for _ in 0..writers {
            let vals: Vec<u64> =
                (0..per_writer).map(|_| rng.next_u64() >> (rng.below(64) as u32)).collect();
            streams.push(vals);
        }
        let expect_count = (writers * per_writer) as u64;
        let mut expect_counter = expect_count; // one inc() per observation, plus add(v % 3)
        let mut expect_sum = 0u64;
        let mut expect_buckets: BTreeMap<u64, u64> = BTreeMap::new();
        for &v in streams.iter().flatten() {
            expect_counter += v % 3;
            expect_sum = expect_sum.wrapping_add(v); // atomic sum wraps too
            let bits = 64 - v.leading_zeros() as usize;
            let le = if bits >= 64 { u64::MAX } else { (1u64 << bits) - 1 };
            *expect_buckets.entry(le).or_insert(0) += 1;
        }
        let (c, h) = (Counter::default(), Histogram::default());
        let done = AtomicBool::new(false);
        let (c, h, done) = (&c, &h, &done);
        std::thread::scope(|s| {
            let reader = s.spawn(move || {
                let (mut last_c, mut last_n) = (0u64, 0u64);
                while !done.load(Relaxed) {
                    let (now_c, now_n) = (c.get(), h.count());
                    assert!(now_c >= last_c, "counter moved backwards");
                    assert!(now_n >= last_n, "histogram count moved backwards");
                    (last_c, last_n) = (now_c, now_n);
                    let hs = h.snapshot(); // bounded retry must return mid-fire
                    assert!(hs.count <= expect_count);
                }
            });
            let mut handles = Vec::new();
            for vals in &streams {
                handles.push(s.spawn(move || {
                    for &v in vals {
                        c.inc();
                        c.add(v % 3);
                        h.observe(v);
                    }
                }));
            }
            for t in handles {
                t.join().unwrap();
            }
            done.store(true, Relaxed);
            reader.join().unwrap();
        });
        // writers quiescent: the snapshot is exact, and nothing was lost
        assert_eq!(c.get(), expect_counter, "seed {seed}: counter lost increments");
        let snap = h.snapshot();
        assert_eq!(snap.count, expect_count, "seed {seed}: histogram lost observations");
        assert_eq!(snap.sum, expect_sum, "seed {seed}: histogram lost sum");
        let want: Vec<(u64, u64)> = expect_buckets.into_iter().collect();
        assert_eq!(snap.buckets, want, "seed {seed}: per-bucket counts drifted");
    }
}

/// Property: magnitude n:m keeps exactly the top-n magnitudes per group.
#[test]
fn prop_magnitude_nm_optimal_per_group() {
    for seed in 0..TRIALS {
        let mut rng = Rng::new(seed ^ 0xF0);
        let (r, c) = (8, 32);
        let w = Tensor::new(vec![r, c], (0..r * c).map(|_| rng.normal_f32()).collect());
        let (wp, mask) = magnitude_prune_nm(&w, 2, 4);
        for row in 0..r {
            for g in (0..c).step_by(4) {
                let mut kept: Vec<f32> = Vec::new();
                let mut dropped: Vec<f32> = Vec::new();
                for j in g..g + 4 {
                    if mask.at2(row, j) == 1.0 {
                        kept.push(w.at2(row, j).abs());
                    } else {
                        dropped.push(w.at2(row, j).abs());
                    }
                }
                let min_kept = kept.iter().cloned().fold(f32::INFINITY, f32::min);
                let max_drop = dropped.iter().cloned().fold(0.0, f32::max);
                assert!(min_kept >= max_drop - 1e-6);
            }
        }
        assert!((wp.sparsity() - 0.5).abs() < 1e-9);
    }
}
