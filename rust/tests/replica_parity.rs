//! Differential suite for the admission router: **putting a router in
//! front of the engine must never change what any request decodes.**
//!
//! * A 1-replica [`Router`] is token-for-token identical to the bare
//!   [`ServeEngine`] across packed formats (dense / CSR / quantized n:m) —
//!   the router only relocates the admission decision, and per-request
//!   streams depend on nothing but prompt and seed.
//! * An N-replica drain returns every replica's `CacheBudget` to exactly
//!   zero — the per-replica budget split leaks nothing.
//! * Chaos: a burst of clients against 2 replicas where one client
//!   disconnects mid-stream and both bounded queues are full at admission
//!   time. Cancellation lands on the owning replica (sticky routing), 429s
//!   are shaped as fleet-wide capacity, and the drain is clean.

use std::collections::BTreeMap;

use sparsegpt::model::init::init_params;
use sparsegpt::model::layout::{FlatParams, PRUNABLE_KINDS};
use sparsegpt::model::ModelCfg;
use sparsegpt::serve::{
    EngineOptions, RequestSource, Router, SchedulerPolicy, ServeEngine, ServeEvent, ServeRequest,
    SparseModel,
};
use sparsegpt::solver::magnitude::{magnitude_prune, magnitude_prune_nm};
use sparsegpt::sparse::{PackFormat, PackPolicy};
use sparsegpt::util::prng::Rng;

const TRIALS: u64 = 4;

fn cfg() -> ModelCfg {
    ModelCfg::from_dims("replica-parity", 8, 2, 2, 1, 1, 13, 6)
}

/// Prune every prunable linear of a fresh model with `f`.
fn pruned_params(
    cfg: &ModelCfg,
    seed: u64,
    f: impl Fn(&sparsegpt::tensor::Tensor) -> sparsegpt::tensor::Tensor,
) -> FlatParams {
    let mut fp = init_params(cfg, seed);
    for layer in 0..cfg.layers {
        for kind in PRUNABLE_KINDS {
            let w = f(&fp.get_linear(kind, layer).unwrap());
            fp.set_linear(kind, layer, &w).unwrap();
        }
    }
    fp
}

/// One model per packed format the issue pins: dense, CSR, quantized n:m.
fn models() -> Vec<(&'static str, SparseModel)> {
    let cfg = cfg();
    let unstructured = pruned_params(&cfg, 3, |w| magnitude_prune(w, 0.5).0);
    let nm = pruned_params(&cfg, 4, |w| magnitude_prune_nm(w, 2, 4).0);
    vec![
        (
            "dense",
            SparseModel::from_params(&unstructured, &PackPolicy::with_format(PackFormat::Dense))
                .unwrap(),
        ),
        (
            "csr",
            SparseModel::from_params(&unstructured, &PackPolicy::with_format(PackFormat::Csr))
                .unwrap(),
        ),
        (
            "qnm-8",
            SparseModel::from_params(
                &nm,
                &PackPolicy::with_format(PackFormat::QNm { bits: 8, group: 0 }),
            )
            .unwrap(),
        ),
    ]
}

/// Random workload: mixed prompt lengths (past the attention window, so
/// prefill evicts), staggered arrivals, mixed token budgets.
fn workload(rng: &mut Rng, vocab: usize, seq: usize) -> Vec<(usize, ServeRequest)> {
    let n = 2 + rng.below(5);
    (0..n)
        .map(|i| {
            let plen = 1 + rng.below(3 * seq);
            let prompt: Vec<i32> = (0..plen).map(|_| rng.below(vocab) as i32).collect();
            (
                rng.below(4),
                ServeRequest {
                    id: i as u64,
                    prompt,
                    max_new_tokens: 1 + rng.below(2 * seq),
                    seed: rng.next_u64(),
                    model: None,
                },
            )
        })
        .collect()
}

fn sorted_streams(finished: &[sparsegpt::serve::FinishedRequest]) -> Vec<(u64, Vec<i32>)> {
    let mut out: Vec<(u64, Vec<i32>)> =
        finished.iter().map(|f| (f.id, f.tokens.clone())).collect();
    out.sort_by_key(|(id, _)| *id);
    out
}

#[test]
fn single_replica_router_matches_bare_engine_on_all_packed_formats() {
    for (label, model) in models() {
        let (vocab, seq) = (model.cfg.vocab, model.cfg.seq);
        for seed in 0..TRIALS {
            let mut rng = Rng::new(seed ^ 0x707E);
            let reqs = workload(&mut rng, vocab, seq);
            let opts = EngineOptions {
                policy: SchedulerPolicy {
                    max_batch: 1 + rng.below(4),
                    max_wait: rng.below(3),
                    queue_cap: 16,
                    max_prefill_tokens: [0, seq][rng.below(2)],
                },
                temperature: [0.0, 0.9][rng.below(2)],
                top_k: 4,
                prefill_chunk: [0, 2][rng.below(2)],
                cache_budget_bytes: [0, model.cache_bytes()][rng.below(2)],
                ..EngineOptions::default()
            };
            let bare = ServeEngine::new(&model, opts).run(reqs.clone(), &mut |_| {}).unwrap();
            let routed = Router::new(&model, opts, 1).run(reqs, &mut |_| {}).unwrap();
            assert_eq!(
                sorted_streams(&routed.total.finished),
                sorted_streams(&bare.finished),
                "{label} seed {seed}: a 1-replica router changed a token stream"
            );
            assert_eq!(routed.per_replica.len(), 1, "{label} seed {seed}");
            assert!(
                routed.total.finished.iter().all(|f| f.replica == 0),
                "{label} seed {seed}: single replica must stamp replica 0"
            );
            assert_eq!(routed.total.tokens, bare.tokens, "{label} seed {seed}");
        }
    }
}

#[test]
fn multi_replica_drain_returns_every_replica_budget_to_zero() {
    let (_, model) = models().remove(0);
    let replicas = 3;
    let mut rng = Rng::new(0xD12A1);
    let reqs: Vec<(usize, ServeRequest)> = (0..12)
        .map(|i| {
            let prompt: Vec<i32> =
                (0..4).map(|_| rng.below(model.cfg.vocab) as i32).collect();
            (
                0,
                ServeRequest {
                    id: i as u64,
                    prompt,
                    max_new_tokens: 6,
                    seed: rng.next_u64(),
                    model: None,
                },
            )
        })
        .collect();
    let opts = EngineOptions {
        policy: SchedulerPolicy { max_batch: 2, max_wait: 0, queue_cap: 16, max_prefill_tokens: 0 },
        temperature: 0.0,
        top_k: 0,
        // a *total* budget of 6 cache slots: each replica gets 2
        cache_budget_bytes: 6 * model.cache_bytes(),
        ..EngineOptions::default()
    };
    let out = Router::new(&model, opts, replicas).run(reqs, &mut |_| {}).unwrap();
    assert_eq!(out.per_replica.len(), replicas);
    assert_eq!(out.total.finished.len(), 12, "every request must retire");
    let mut tokens = 0;
    for (i, r) in out.per_replica.iter().enumerate() {
        assert_eq!(
            r.cache_bytes_in_use, 0,
            "replica {i} drained with cache bytes still reserved"
        );
        assert!(r.peak_cache_bytes > 0, "replica {i} never admitted a request");
        tokens += r.tokens;
    }
    assert_eq!(tokens, out.total.tokens, "aggregate token count must be the per-replica sum");
    assert_eq!(out.total.cache_bytes_in_use, 0);
}

/// A burst of client submissions that doesn't respect backpressure (like
/// the network front door): everything lands at once, the router sheds the
/// overflow, and one client hangs up mid-stream.
struct ChaosSource {
    burst: Vec<ServeRequest>,
    sent: bool,
    victim: u64,
    cut_after: usize,
    rejected: Vec<(u64, usize, usize)>,
    cancelled: Vec<(u64, usize)>,
    finished: Vec<u64>,
}

impl RequestSource for ChaosSource {
    fn poll(&mut self, _step: usize, _queue_free: usize) -> Vec<ServeRequest> {
        if self.sent {
            Vec::new()
        } else {
            self.sent = true;
            std::mem::take(&mut self.burst)
        }
    }
    fn take_cancelled(&mut self, _step: usize) -> Vec<u64> {
        Vec::new()
    }
    fn closed(&self) -> bool {
        self.sent
    }
    fn rejected(&mut self, req: &ServeRequest, queue: usize, cap: usize) {
        self.rejected.push((req.id, queue, cap));
    }
    fn token(&mut self, id: u64, index: usize, _token: i32) -> bool {
        // the victim's client drops its connection after `cut_after` tokens
        !(id == self.victim && index + 1 >= self.cut_after)
    }
    fn finished(&mut self, fin: &sparsegpt::serve::FinishedRequest) {
        self.finished.push(fin.id);
    }
    fn cancelled(&mut self, id: u64, tokens: usize) {
        self.cancelled.push((id, tokens));
    }
}

#[test]
fn chaos_burst_sticky_cancel_and_fleet_shaped_backpressure() {
    let (_, model) = models().remove(0);
    let mut rng = Rng::new(0xC4A05);
    // six clients against 2 replicas x queue_cap 2: four admitted, two shed.
    // Client 0 wants an effectively unbounded stream and disconnects after
    // two tokens — its cancel must land on whichever replica owns it.
    let burst: Vec<ServeRequest> = (0..6)
        .map(|i| ServeRequest {
            id: i as u64,
            prompt: (0..4).map(|_| rng.below(model.cfg.vocab) as i32).collect(),
            max_new_tokens: if i == 0 { 10_000 } else { 6 },
            seed: rng.next_u64(),
            model: None,
        })
        .collect();
    let mut source = ChaosSource {
        burst,
        sent: false,
        victim: 0,
        cut_after: 2,
        rejected: Vec::new(),
        cancelled: Vec::new(),
        finished: Vec::new(),
    };
    let opts = EngineOptions {
        policy: SchedulerPolicy { max_batch: 1, max_wait: 0, queue_cap: 2, max_prefill_tokens: 0 },
        temperature: 0.0,
        top_k: 0,
        ..EngineOptions::default()
    };
    let mut events = Vec::new();
    let out = Router::new(&model, opts, 2)
        .run_source(&mut source, &mut |e| events.push(e.clone()))
        .unwrap();

    // 429s fire only once *both* bounded queues are full, and report the
    // fleet-wide capacity (2 replicas x queue_cap 2)
    let mut shed: Vec<u64> = source.rejected.iter().map(|&(id, _, _)| id).collect();
    shed.sort_unstable();
    assert_eq!(shed, vec![4, 5], "exactly the overflow past fleet capacity is shed");
    for &(id, queue, cap) in &source.rejected {
        assert_eq!((queue, cap), (4, 4), "429 for {id} must be shaped as full fleet capacity");
    }
    assert_eq!(out.total.rejected, 2);

    // sticky ownership: the victim's cancellation retired on the replica
    // that enqueued it
    let mut enqueued: BTreeMap<u64, usize> = BTreeMap::new();
    let mut cancelled: BTreeMap<u64, usize> = BTreeMap::new();
    for e in &events {
        match e {
            ServeEvent::Enqueued { id, replica, .. } => {
                enqueued.insert(*id, *replica);
            }
            ServeEvent::Cancelled { id, replica, .. } => {
                cancelled.insert(*id, *replica);
            }
            _ => {}
        }
    }
    assert_eq!(enqueued.len(), 4, "four clients admitted");
    assert_eq!(
        cancelled.get(&0),
        enqueued.get(&0),
        "cancel must reach the replica that owns request 0"
    );
    assert_eq!(out.total.cancelled, 1);
    let (cancel_id, cancel_tokens) = source.cancelled[0];
    assert_eq!(cancel_id, 0);
    assert!(
        (2..10_000).contains(&cancel_tokens),
        "victim retired early with {cancel_tokens} tokens"
    );

    // the survivors finish, spread across both replicas
    let mut done = source.finished.clone();
    done.sort_unstable();
    assert_eq!(done, vec![1, 2, 3]);
    let replicas_used: std::collections::BTreeSet<usize> = enqueued.values().copied().collect();
    assert_eq!(replicas_used.len(), 2, "the burst must fan out across both replicas");

    // clean drain: every replica's budget is back to zero
    for (i, r) in out.per_replica.iter().enumerate() {
        assert_eq!(r.cache_bytes_in_use, 0, "replica {i} leaked cache reservation");
    }
}
