//! Golden test for the serve job's JSONL event contract on the reference
//! backend: a real zero-artifact run (no data, no checkpoints, no PJRT —
//! seed-0 init + synthetic calibration fallbacks engage) proceeds through
//! prune → quantized pack (`qcsr:4`, written to disk) → KV-cached
//! continuous-batching decode, and its lifecycle lines (`job-started`,
//! `checkpoint-packed`, `request-enqueued`, `batch-formed`,
//! `prefill-started`, `cache-evicted`, `request-cancelled`,
//! `request-finished`, `engine-drained`, `job-finished`) must serialize
//! exactly as pinned in `golden/serve_events.jsonl`. Wall-clock fields
//! (`secs`, `tokens_per_sec`) and filesystem fields (`path`, `bytes`) are
//! normalized; everything else — arrival order, batch formation, prefill
//! chunking, eviction counts, join/retire/cancel steps, and the quantized
//! pack's `density` 0.5 / `effective_bits` 3 (the solver zeroes exactly
//! round(p·numel) per selection window, so nano at 50% is exact) — is
//! schedule-determined and pinned.
//!
//! The workload (3 requests with 130-token prompts arriving one per step
//! into a batch of 2 with max_wait 1, 3 tokens each, and a scripted
//! `cancel=1@3` mid-stream disconnect) exercises every scheduler + cache
//! behavior on nano's 128-token window: the idle wait, a full-batch
//! launch, a 5-chunk prefill whose overlong prompt evicts 2 ring entries
//! (130 into 128), one further eviction per decode step once the ring is
//! full, a mid-decode cancellation whose freed batch slot is refilled the
//! same step, and a clean drain with the cache budget back at zero.
//!
//! Hand-verified schedule: id0 arrives at step 0 and waits (partial batch,
//! max_wait 1); id1 arrives at step 1 forming the full batch — both
//! prefill at step 1 (evicting 2 each) and sample their first token from
//! the prefill logits; their decode at step 2 evicts 1 each (tokens 2 of
//! 3). At step 3 id1's client disconnects — it retires as cancelled with
//! 2 tokens streamed, and id2 (queued since step 2) immediately joins the
//! freed slot, prefilling at step 3 while id0 decodes its third token and
//! finishes. id2 decodes at steps 4 and 5 and finishes; the engine drains
//! after 6 steps with 8 generated tokens (3 + 2 + 3), 2 finished
//! requests, 1 cancelled, and 0 cache bytes still reserved.

use sparsegpt::api::{JobSpec, JsonlSink, ServeSpec, Session};
use sparsegpt::harness::Workspace;
use sparsegpt::runtime::ReferenceBackend;
use sparsegpt::sparse::PackFormat;
use sparsegpt::util::json::Json;

const PINNED: [&str; 10] = [
    "job-started",
    "checkpoint-packed",
    "request-enqueued",
    "batch-formed",
    "prefill-started",
    "cache-evicted",
    "request-cancelled",
    "request-finished",
    "engine-drained",
    "job-finished",
];

fn run_serve_jsonl() -> String {
    let dir = std::env::temp_dir().join(format!("sgpt_serve_golden_{}", std::process::id()));
    let ws = Workspace {
        data_dir: dir.join("data"), // absent: the synthetic-calibration fallback engages
        ckpt_dir: dir.join("checkpoints"), // absent: the seed-0 init fallback engages
        report_dir: dir.join("reports"),
        rt: Box::new(ReferenceBackend::new()),
    };
    let mut spec = ServeSpec::new("nano");
    spec.requests = 3;
    spec.max_new_tokens = 3;
    spec.prompt_len = 130; // 2 past nano's 128-token window: prefill evicts
    spec.arrival_every = 1;
    spec.max_batch = 2;
    spec.max_wait = 1;
    spec.temperature = 0.0; // greedy: the schedule alone determines events
    spec.calib = 4;
    // id1's client disconnects at step 3, mid-stream (2 of 3 tokens out)
    spec.cancel = vec![(1, 3)];
    // quantized leg: pack q4 CSR to disk so checkpoint-packed is emitted
    // with the effective-bits payload (0.5 * 4 + 1 = 3 bits/weight)
    spec.format = PackFormat::QCsr { bits: 4, group: 0 };
    spec.save_store = Some(dir.join("nano-golden.spkt"));
    let mut sink = JsonlSink::new(Vec::new());
    let mut session = Session::with_workspace(ws);
    session.run(&JobSpec::Serve(spec), &mut sink).unwrap();
    std::fs::remove_dir_all(&dir).ok();
    String::from_utf8(sink.into_inner()).unwrap()
}

#[test]
fn serve_lifecycle_events_match_golden() {
    let text = run_serve_jsonl();
    let mut pinned = String::new();
    for line in text.lines() {
        let mut v = Json::parse(line)
            .unwrap_or_else(|e| panic!("unparseable event line {line:?}: {e:#}"));
        let reason = v.get("reason").unwrap().as_str().unwrap().to_string();
        if PINNED.contains(&reason.as_str()) {
            // wall-clock and filesystem fields are the only nondeterminism
            if let Json::Obj(m) = &mut v {
                for key in ["secs", "tokens_per_sec", "bytes"] {
                    if m.contains_key(key) {
                        m.insert(key.to_string(), Json::Num(0.0));
                    }
                }
                if reason == "checkpoint-packed" {
                    m.insert("path".to_string(), Json::Str("<path>".to_string()));
                }
            }
            pinned.push_str(&v.to_string_compact());
            pinned.push('\n');
        }
    }
    let want = include_str!("golden/serve_events.jsonl");
    assert_eq!(
        pinned, want,
        "serve JSONL event schema drifted — update \
         rust/tests/golden/serve_events.jsonl deliberately (downstream \
         consumers parse these lines)"
    );

    // the full stream is well-formed and the lifecycle is complete
    let mut enqueued = 0;
    let mut prefilled = 0;
    let mut evicted = 0;
    let mut finished = 0;
    let mut cancelled = 0;
    let mut drained = 0;
    let mut packed = 0;
    let mut ok = false;
    for line in text.lines() {
        let v = Json::parse(line).unwrap();
        match v.get("reason").unwrap().as_str().unwrap() {
            "checkpoint-packed" => {
                packed += 1;
                // the Fig.-6 point, live: 50% sparse + 4-bit + mask = 3.0
                let bits = v.get("effective_bits").unwrap().as_f64().unwrap();
                assert!((bits - 3.0).abs() < 1e-9, "effective_bits {bits}");
                assert!(bits <= 3.1, "acceptance ceiling");
                assert_eq!(v.get("formats").unwrap().as_str().unwrap(), "qcsr:12");
            }
            "request-enqueued" => enqueued += 1,
            "prefill-started" => {
                prefilled += 1;
                assert_eq!(v.get("prompt_tokens").unwrap().as_usize().unwrap(), 130);
                assert_eq!(v.get("chunks").unwrap().as_usize().unwrap(), 5);
            }
            "cache-evicted" => evicted += v.get("evicted").unwrap().as_usize().unwrap(),
            "request-finished" => finished += 1,
            "request-cancelled" => {
                cancelled += 1;
                // the scripted disconnect lands mid-stream: 2 of 3 tokens
                assert_eq!(v.get("id").unwrap().as_usize().unwrap(), 1);
                assert_eq!(v.get("step").unwrap().as_usize().unwrap(), 3);
                assert_eq!(v.get("tokens").unwrap().as_usize().unwrap(), 2);
            }
            "engine-drained" => {
                drained += 1;
                assert_eq!(v.get("requests").unwrap().as_usize().unwrap(), 2);
                assert_eq!(v.get("tokens").unwrap().as_usize().unwrap(), 8);
                assert_eq!(v.get("cancelled").unwrap().as_usize().unwrap(), 1);
                // the cancelled request's reservation came back to the budget
                assert_eq!(v.get("cache_bytes_in_use").unwrap().as_usize().unwrap(), 0);
            }
            "job-finished" => ok = matches!(v.get("ok").unwrap(), Json::Bool(true)),
            _ => {}
        }
    }
    assert_eq!(packed, 1, "the quantized .spkt is packed exactly once");
    assert_eq!(enqueued, 3, "every synthetic request is enqueued once");
    assert_eq!(prefilled, 3, "every request prefills exactly once");
    assert_eq!(
        evicted, 11,
        "2 prefill evictions per request + 1 per decode step (2 + 1 + 2)"
    );
    assert_eq!(finished, 2, "both surviving requests retire exactly once");
    assert_eq!(cancelled, 1, "the scripted disconnect cancels exactly once");
    assert_eq!(drained, 1);
    assert!(ok, "serve job must finish ok");
}
