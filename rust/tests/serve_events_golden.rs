//! Golden test for the serve job's JSONL event contract on the reference
//! backend: a real zero-artifact run (no data, no checkpoints, no PJRT —
//! seed-0 init + synthetic calibration fallbacks engage) proceeds through
//! prune → pack → continuous-batching decode, and its lifecycle lines
//! (`job-started`, `request-enqueued`, `batch-formed`, `request-finished`,
//! `engine-drained`, `job-finished`) must serialize exactly as pinned in
//! `golden/serve_events.jsonl`. Wall-clock fields (`secs`,
//! `tokens_per_sec`) are normalized to 0; everything else — arrival order,
//! batch formation, join/retire steps — is schedule-determined and exact.
//!
//! The workload (5 requests arriving one per step into a batch of 2 with
//! max_wait 1, 3 tokens each) is chosen to exercise every scheduler
//! behavior: the idle wait, a full-batch launch, mid-run relaunch, and a
//! trailing partial batch.

use sparsegpt::api::{JobSpec, JsonlSink, ServeSpec, Session};
use sparsegpt::harness::Workspace;
use sparsegpt::runtime::ReferenceBackend;
use sparsegpt::util::json::Json;

const PINNED: [&str; 6] = [
    "job-started",
    "request-enqueued",
    "batch-formed",
    "request-finished",
    "engine-drained",
    "job-finished",
];

fn run_serve_jsonl() -> String {
    let dir = std::env::temp_dir().join(format!("sgpt_serve_golden_{}", std::process::id()));
    let ws = Workspace {
        data_dir: dir.join("data"), // absent: the synthetic-calibration fallback engages
        ckpt_dir: dir.join("checkpoints"), // absent: the seed-0 init fallback engages
        report_dir: dir.join("reports"),
        rt: Box::new(ReferenceBackend::new()),
    };
    let mut spec = ServeSpec::new("nano");
    spec.requests = 5;
    spec.max_new_tokens = 3;
    spec.prompt_len = 4;
    spec.arrival_every = 1;
    spec.max_batch = 2;
    spec.max_wait = 1;
    spec.temperature = 0.0; // greedy: the schedule alone determines events
    spec.calib = 4;
    let mut sink = JsonlSink::new(Vec::new());
    let mut session = Session::with_workspace(ws);
    session.run(&JobSpec::Serve(spec), &mut sink).unwrap();
    std::fs::remove_dir_all(&dir).ok();
    String::from_utf8(sink.into_inner()).unwrap()
}

#[test]
fn serve_lifecycle_events_match_golden() {
    let text = run_serve_jsonl();
    let mut pinned = String::new();
    for line in text.lines() {
        let mut v = Json::parse(line)
            .unwrap_or_else(|e| panic!("unparseable event line {line:?}: {e:#}"));
        let reason = v.get("reason").unwrap().as_str().unwrap().to_string();
        if PINNED.contains(&reason.as_str()) {
            // wall-clock fields are the only nondeterminism; pin them
            if let Json::Obj(m) = &mut v {
                for key in ["secs", "tokens_per_sec"] {
                    if m.contains_key(key) {
                        m.insert(key.to_string(), Json::Num(0.0));
                    }
                }
            }
            pinned.push_str(&v.to_string_compact());
            pinned.push('\n');
        }
    }
    let want = include_str!("golden/serve_events.jsonl");
    assert_eq!(
        pinned, want,
        "serve JSONL event schema drifted — update \
         rust/tests/golden/serve_events.jsonl deliberately (downstream \
         consumers parse these lines)"
    );

    // the full stream is well-formed and the lifecycle is complete
    let mut enqueued = 0;
    let mut finished = 0;
    let mut drained = 0;
    let mut ok = false;
    for line in text.lines() {
        let v = Json::parse(line).unwrap();
        match v.get("reason").unwrap().as_str().unwrap() {
            "request-enqueued" => enqueued += 1,
            "request-finished" => finished += 1,
            "engine-drained" => {
                drained += 1;
                assert_eq!(v.get("requests").unwrap().as_usize().unwrap(), 5);
                assert_eq!(v.get("tokens").unwrap().as_usize().unwrap(), 15);
            }
            "job-finished" => ok = matches!(v.get("ok").unwrap(), Json::Bool(true)),
            _ => {}
        }
    }
    assert_eq!(enqueued, 5, "every synthetic request is enqueued once");
    assert_eq!(finished, 5, "every request retires exactly once");
    assert_eq!(drained, 1);
    assert!(ok, "serve job must finish ok");
}
