//! Golden test for the sweep job's JSONL event contract on the reference
//! backend: a real 3-variant sweep — including the joint sparse+quant
//! mode (Eq. 7, `sparsegpt-50%+4bit`) — runs end-to-end (no PJRT, no
//! artifacts) and its `sweep-variant` / `job-finished` lines must
//! serialize exactly as pinned in `golden/sweep_events.jsonl` (wall-clock
//! seconds normalized to 0 — everything else is deterministic).
//! Downstream consumers key on these lines to track sweep progress.

use sparsegpt::api::{JobSpec, JsonlSink, PruneSpec, Session, SweepSpec};
use sparsegpt::harness::{generate_data, Workspace};
use sparsegpt::model::checkpoint::Checkpoint;
use sparsegpt::model::init::init_params;
use sparsegpt::runtime::ReferenceBackend;
use sparsegpt::util::json::Json;

fn run_sweep_jsonl() -> String {
    let dir = std::env::temp_dir().join(format!("sgpt_sweep_golden_{}", std::process::id()));
    let data_dir = dir.join("data");
    let ckpt_dir = dir.join("checkpoints");
    generate_data(&data_dir, 1, 0).unwrap(); // minimum-size corpora
    let ws = Workspace {
        data_dir,
        ckpt_dir: ckpt_dir.clone(),
        report_dir: dir.join("reports"),
        rt: Box::new(ReferenceBackend::new()),
    };
    let cfg = ws.config("nano").unwrap();
    Checkpoint {
        config_name: "nano".into(),
        step: 0,
        params: init_params(&cfg, 0).data,
        adam: None,
    }
    .save(Checkpoint::path_for(&ckpt_dir, "nano", ""))
    .unwrap();

    let spec = SweepSpec::new("nano")
        .variant(PruneSpec::sparsegpt(0.5))
        .variant(PruneSpec::magnitude(0.5))
        .variant(PruneSpec::sparsegpt(0.5).with_quant_bits(4))
        .dataset("synth-wiki")
        .calib(8)
        .max_segments(2);
    let mut sink = JsonlSink::new(Vec::new());
    let mut session = Session::with_workspace(ws);
    session.run(&JobSpec::Sweep(spec), &mut sink).unwrap();
    std::fs::remove_dir_all(&dir).ok();
    String::from_utf8(sink.into_inner()).unwrap()
}

#[test]
fn sweep_variant_and_finish_events_match_golden() {
    let text = run_sweep_jsonl();
    let mut pinned = String::new();
    for line in text.lines() {
        let mut v = Json::parse(line)
            .unwrap_or_else(|e| panic!("unparseable event line {line:?}: {e:#}"));
        let reason = v.get("reason").unwrap().as_str().unwrap().to_string();
        if reason == "sweep-variant" || reason == "job-finished" {
            // wall-clock is the one nondeterministic field; pin it
            if let Json::Obj(m) = &mut v {
                if m.contains_key("secs") {
                    m.insert("secs".to_string(), Json::Num(0.0));
                }
            }
            pinned.push_str(&v.to_string_compact());
            pinned.push('\n');
        }
    }
    let want = include_str!("golden/sweep_events.jsonl");
    assert_eq!(
        pinned, want,
        "sweep JSONL event schema drifted — update rust/tests/golden/sweep_events.jsonl \
         deliberately (downstream consumers parse these lines)"
    );
    // the full stream is well-formed: every line has a reason, the job
    // finished ok, and both variants produced eval results
    let mut evals = 0;
    let mut finished_ok = false;
    for line in text.lines() {
        let v = Json::parse(line).unwrap();
        let reason = v.get("reason").unwrap().as_str().unwrap().to_string();
        if reason == "eval-result" {
            assert_eq!(v.get("dataset").unwrap().as_str().unwrap(), "synth-wiki");
            evals += 1;
        }
        if reason == "job-finished" {
            finished_ok = matches!(v.get("ok").unwrap(), Json::Bool(true));
        }
    }
    assert_eq!(evals, 3, "one perplexity row per variant");
    assert!(finished_ok);
}
