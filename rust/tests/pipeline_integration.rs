//! Integration tests over the full coordinator pipeline on the `nano`
//! config: real calibration data, end-to-end invariants.
//!
//! Every test runs on the pure-Rust reference backend (always available —
//! these are the paper's e2e claims, executed in CI on every push), and
//! additionally on the PJRT backend when compiled artifacts are present.

use sparsegpt::coordinator::{CalibChunks, PruneMethod, PruneOptions, Pruner, SkipSpec};
use sparsegpt::data::Dataset;
use sparsegpt::eval::perplexity;
use sparsegpt::model::init::init_params;
use sparsegpt::model::layout::{FlatParams, LinearKind, PRUNABLE_KINDS};
use sparsegpt::model::stats::ModelStats;
use sparsegpt::model::ModelCfg;
use sparsegpt::runtime::{Backend, ReferenceBackend, Runtime};
use sparsegpt::solver::sparsegpt_ref::Pattern;
use sparsegpt::util::prng::Rng;

/// The backends to exercise: the reference interpreter always; the PJRT
/// runtime when `make artifacts` has run. (The PJRT client is not Sync, so
/// each test builds its own instances.)
fn backends() -> Vec<Box<dyn Backend>> {
    let mut v: Vec<Box<dyn Backend>> = vec![Box::new(ReferenceBackend::new())];
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if std::path::Path::new(dir).join("manifest.json").exists() {
        v.push(Box::new(Runtime::with_dir(dir).expect("runtime")));
    }
    v
}

/// The shared corpus fixture — the exact corpus the CLI's zero-setup
/// fallback uses (seed-fixed, backend-independent), generated once per test
/// binary instead of once per test per backend.
fn calib_corpus() -> &'static Dataset {
    static CORPUS: std::sync::OnceLock<Dataset> = std::sync::OnceLock::new();
    CORPUS.get_or_init(sparsegpt::harness::synthetic_calibration_corpus)
}

/// A small self-contained workload: fresh nano params + synthetic calib
/// (8 segments = one chunk — enough signal, CI-friendly on the interpreter).
fn setup(rt: &dyn Backend) -> (ModelCfg, FlatParams, CalibChunks, &'static Dataset) {
    let cfg = rt.config("nano").unwrap();
    let params = init_params(&cfg, 42);
    let ds = calib_corpus();
    let mut rng = Rng::new(0);
    let segs = ds.calibration_segments(&mut rng, 8, cfg.seq).unwrap();
    let chunks = CalibChunks::new(&cfg, &segs).unwrap();
    (cfg, params, chunks, ds)
}

#[test]
fn pipeline_prunes_to_exact_density_and_runs() {
    for be in backends() {
        let rt = be.as_ref();
        let (_cfg, params, chunks, ds) = setup(rt);
        let opts = PruneOptions {
            method: PruneMethod::SparseGpt {
                pattern: Pattern::Unstructured(0.5),
                quant_bits: None,
            },
            ..Default::default()
        };
        let out = Pruner::new(rt).prune(params.clone(), &chunks, &opts).unwrap();
        let s = out.overall_sparsity();
        assert!((s - 0.5).abs() < 0.01, "[{}] sparsity {s}", rt.name());
        // every matrix individually close to 50%
        for r in &out.reports {
            assert!(!r.skipped);
            assert!((r.sparsity - 0.5).abs() < 0.02, "[{}] {:?} {}", rt.name(), r.kind, r.sparsity);
        }
        // embeddings untouched
        assert_eq!(
            out.params.region("tok_embed").unwrap(),
            params.region("tok_embed").unwrap()
        );
        // the pruned model still produces finite perplexity
        let ppl = perplexity(rt, &out.params, ds, 4).unwrap();
        assert!(ppl.ppl.is_finite() && ppl.ppl > 1.0, "[{}] ppl {}", rt.name(), ppl.ppl);
    }
}

#[test]
fn pipeline_nm_patterns_validate() {
    for be in backends() {
        let rt = be.as_ref();
        let (_cfg, params, chunks, _ds) = setup(rt);
        for (n, m) in [(2usize, 4usize), (4, 8)] {
            let opts = PruneOptions {
                method: PruneMethod::SparseGpt { pattern: Pattern::NM(n, m), quant_bits: None },
                ..Default::default()
            };
            let out = Pruner::new(rt).prune(params.clone(), &chunks, &opts).unwrap();
            let stats = ModelStats::collect_nm(&out.params, Some((n, m)));
            assert_eq!(stats.total_nm_violations(), 0, "[{}] {n}:{m}", rt.name());
            assert!((stats.overall_sparsity() - 0.5).abs() < 1e-6);
        }
    }
}

#[test]
fn pipeline_skip_policy_leaves_layers_dense() {
    for be in backends() {
        let rt = be.as_ref();
        let (cfg, params, chunks, _ds) = setup(rt);
        let opts = PruneOptions {
            method: PruneMethod::SparseGpt { pattern: Pattern::NM(2, 4), quant_bits: None },
            skip: SkipSpec::LayerType("fc2".into()),
            ..Default::default()
        };
        let out = Pruner::new(rt).prune(params.clone(), &chunks, &opts).unwrap();
        for l in 0..cfg.layers {
            let fc2_new = out.params.get_linear(LinearKind::Fc2, l).unwrap();
            let fc2_old = params.get_linear(LinearKind::Fc2, l).unwrap();
            assert_eq!(fc2_new, fc2_old, "[{}] fc2 must be untouched", rt.name());
            let q = out.params.get_linear(LinearKind::Wq, l).unwrap();
            assert!(q.sparsity() > 0.4, "[{}] wq must be pruned", rt.name());
        }
    }
}

#[test]
fn pipeline_sparsegpt_beats_magnitude_on_calibration_metric() {
    for be in backends() {
        let rt = be.as_ref();
        let (_cfg, params, chunks, _ds) = setup(rt);
        // record layer errors for both methods; SparseGPT must win on
        // (almost) every matrix — this is the reconstruction guarantee
        let run = |method: PruneMethod| {
            let opts = PruneOptions { method, record_errors: true, ..Default::default() };
            Pruner::new(rt).prune(params.clone(), &chunks, &opts).unwrap()
        };
        let sgpt = run(PruneMethod::SparseGpt {
            pattern: Pattern::Unstructured(0.5),
            quant_bits: None,
        });
        let mag = run(PruneMethod::Magnitude { pattern: Pattern::Unstructured(0.5) });
        let mut wins = 0;
        let mut total = 0;
        for (a, b) in sgpt.reports.iter().zip(&mag.reports) {
            // the magnitude run's Hessians differ slightly after the first
            // pruned block (activations diverge); layer-0 comparisons are
            // exact
            if let (Some(ea), Some(eb)) = (a.sq_error, b.sq_error) {
                total += 1;
                if ea <= eb {
                    wins += 1;
                }
            }
        }
        assert!(total >= 12, "[{}] only {total} comparisons", rt.name());
        assert!(wins * 10 >= total * 9, "[{}] sparsegpt won only {wins}/{total}", rt.name());
    }
}

#[test]
fn pipeline_quantization_grid_respected() {
    for be in backends() {
        let rt = be.as_ref();
        let (_cfg, params, chunks, _ds) = setup(rt);
        let opts = PruneOptions {
            method: PruneMethod::SparseGpt {
                pattern: Pattern::Unstructured(0.5),
                quant_bits: Some(4),
            },
            ..Default::default()
        };
        let out = Pruner::new(rt).prune(params.clone(), &chunks, &opts).unwrap();
        // kept weights take at most 2^4 distinct values per row
        for kind in PRUNABLE_KINDS {
            let w = out.params.get_linear(kind, 0).unwrap();
            for r in 0..w.rows().min(8) {
                let mut vals: Vec<f32> =
                    w.row(r).iter().cloned().filter(|&v| v != 0.0).collect();
                vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
                vals.dedup_by(|a, b| (*a - *b).abs() < 1e-7);
                assert!(
                    vals.len() <= 16,
                    "[{}] {kind:?} row {r}: {} levels",
                    rt.name(),
                    vals.len()
                );
            }
        }
    }
}

#[test]
fn pipeline_adaprune_runs_and_prunes() {
    for be in backends() {
        let rt = be.as_ref();
        let (_cfg, params, chunks, _ds) = setup(rt);
        let opts = PruneOptions {
            method: PruneMethod::AdaPrune { sparsity: 0.5 },
            record_errors: true,
            ..Default::default()
        };
        let out = Pruner::new(rt).prune(params.clone(), &chunks, &opts).unwrap();
        assert!((out.overall_sparsity() - 0.5).abs() < 0.01, "[{}]", rt.name());
        // AdaPrune must also beat plain magnitude on layer error (it
        // reconstructs on the same magnitude mask)
        let mag = Pruner::new(rt)
            .prune(
                params.clone(),
                &chunks,
                &PruneOptions {
                    method: PruneMethod::Magnitude { pattern: Pattern::Unstructured(0.5) },
                    record_errors: true,
                    ..Default::default()
                },
            )
            .unwrap();
        let (a0, m0) = (
            out.reports[0].sq_error.unwrap(),
            mag.reports[0].sq_error.unwrap(),
        );
        assert!(a0 <= m0 * 1.001, "[{}] adaprune {a0} vs magnitude {m0}", rt.name());
    }
}

#[test]
fn pipeline_deterministic_given_seed() {
    for be in backends() {
        let rt = be.as_ref();
        let (_cfg, params, chunks, _ds) = setup(rt);
        let opts = PruneOptions::default();
        let a = Pruner::new(rt).prune(params.clone(), &chunks, &opts).unwrap();
        let b = Pruner::new(rt).prune(params.clone(), &chunks, &opts).unwrap();
        assert_eq!(a.params.data, b.params.data, "[{}]", rt.name());
    }
}

/// The reference backend also executes the Fig-10 mask-blocksize ablation
/// variants (open vocabulary — any Bs), which PJRT only lowers for `small`.
#[test]
fn pipeline_bs_ablation_runs_on_reference() {
    let be = ReferenceBackend::new();
    let rt: &dyn Backend = &be;
    let (_cfg, params, chunks, _ds) = setup(rt);
    let opts = PruneOptions {
        method: PruneMethod::SparseGptBs { sparsity: 0.5, mask_blocksize: 16 },
        ..Default::default()
    };
    let out = Pruner::new(rt).prune(params, &chunks, &opts).unwrap();
    let s = out.overall_sparsity();
    assert!((s - 0.5).abs() < 0.01, "sparsity {s}");
}
