//! Integration tests over the full coordinator pipeline on the `nano`
//! config: real artifacts, real calibration data, end-to-end invariants.
//! Skipped (trivially pass) when artifacts or data have not been built.

use sparsegpt::coordinator::{
    CalibChunks, PruneMethod, PruneOptions, Pruner, SkipSpec,
};
use sparsegpt::data::corpus::{gen_corpus, CorpusStyle, Lexicon};
use sparsegpt::data::{Dataset, Tokenizer};
use sparsegpt::eval::perplexity;
use sparsegpt::model::init::init_params;
use sparsegpt::model::layout::{FlatParams, LinearKind, PRUNABLE_KINDS};
use sparsegpt::model::stats::ModelStats;
use sparsegpt::model::ModelCfg;
use sparsegpt::runtime::Runtime;
use sparsegpt::solver::sparsegpt_ref::Pattern;
use sparsegpt::util::prng::Rng;

// The PJRT client is not Sync (Rc internals), so each test builds its own
// Runtime; nano artifacts compile in well under a second each.
fn runtime() -> Option<Runtime> {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if !std::path::Path::new(dir).join("manifest.json").exists() {
        eprintln!("artifacts not built; skipping");
        return None;
    }
    Some(Runtime::with_dir(dir).expect("runtime"))
}

/// A small self-contained workload: fresh nano params + synthetic calib.
fn setup(rt: &Runtime) -> (ModelCfg, FlatParams, CalibChunks, Dataset) {
    let cfg = rt.manifest.config("nano").unwrap().clone();
    let params = init_params(&cfg, 42);
    let lex = Lexicon::new(0);
    let text = gen_corpus(&lex, CorpusStyle::C4, 5, 400_000);
    let tok = Tokenizer::train(&text[..100_000]);
    let ds = Dataset::from_text("calib", &tok, &text);
    let mut rng = Rng::new(0);
    let segs = ds.calibration_segments(&mut rng, 16, cfg.seq).unwrap();
    let chunks = CalibChunks::new(&cfg, &segs).unwrap();
    (cfg, params, chunks, ds)
}

#[test]
fn pipeline_prunes_to_exact_density_and_runs() {
    let Some(rt) = runtime() else { return };
    let rt = &rt;
    let (_cfg, params, chunks, ds) = setup(rt);
    let opts = PruneOptions {
        method: PruneMethod::SparseGpt { pattern: Pattern::Unstructured(0.5), quant_bits: None },
        ..Default::default()
    };
    let out = Pruner::new(rt).prune(params.clone(), &chunks, &opts).unwrap();
    let s = out.overall_sparsity();
    assert!((s - 0.5).abs() < 0.01, "sparsity {s}");
    // every matrix individually close to 50%
    for r in &out.reports {
        assert!(!r.skipped);
        assert!((r.sparsity - 0.5).abs() < 0.02, "{:?} {}", r.kind, r.sparsity);
    }
    // embeddings untouched
    assert_eq!(out.params.region("tok_embed").unwrap(), params.region("tok_embed").unwrap());
    // the pruned model still produces finite perplexity
    let ppl = perplexity(rt, &out.params, &ds, 8).unwrap();
    assert!(ppl.ppl.is_finite() && ppl.ppl > 1.0);
}

#[test]
fn pipeline_nm_patterns_validate() {
    let Some(rt) = runtime() else { return };
    let rt = &rt;
    let (_cfg, params, chunks, _ds) = setup(rt);
    for (n, m) in [(2usize, 4usize), (4, 8)] {
        let opts = PruneOptions {
            method: PruneMethod::SparseGpt { pattern: Pattern::NM(n, m), quant_bits: None },
            ..Default::default()
        };
        let out = Pruner::new(rt).prune(params.clone(), &chunks, &opts).unwrap();
        let stats = ModelStats::collect_nm(&out.params, Some((n, m)));
        assert_eq!(stats.total_nm_violations(), 0, "{n}:{m}");
        assert!((stats.overall_sparsity() - 0.5).abs() < 1e-6);
    }
}

#[test]
fn pipeline_skip_policy_leaves_layers_dense() {
    let Some(rt) = runtime() else { return };
    let rt = &rt;
    let (cfg, params, chunks, _ds) = setup(rt);
    let opts = PruneOptions {
        method: PruneMethod::SparseGpt { pattern: Pattern::NM(2, 4), quant_bits: None },
        skip: SkipSpec::LayerType("fc2".into()),
        ..Default::default()
    };
    let out = Pruner::new(rt).prune(params.clone(), &chunks, &opts).unwrap();
    for l in 0..cfg.layers {
        let fc2_new = out.params.get_linear(LinearKind::Fc2, l).unwrap();
        let fc2_old = params.get_linear(LinearKind::Fc2, l).unwrap();
        assert_eq!(fc2_new, fc2_old, "fc2 must be untouched");
        let q = out.params.get_linear(LinearKind::Wq, l).unwrap();
        assert!(q.sparsity() > 0.4, "wq must be pruned");
    }
}

#[test]
fn pipeline_sparsegpt_beats_magnitude_on_calibration_metric() {
    let Some(rt) = runtime() else { return };
    let rt = &rt;
    let (_cfg, params, chunks, _ds) = setup(rt);
    // record layer errors for both methods; SparseGPT must win on (almost)
    // every matrix — this is the reconstruction guarantee
    let run = |method: PruneMethod| {
        let opts = PruneOptions { method, record_errors: true, ..Default::default() };
        Pruner::new(rt).prune(params.clone(), &chunks, &opts).unwrap()
    };
    let sgpt = run(PruneMethod::SparseGpt {
        pattern: Pattern::Unstructured(0.5),
        quant_bits: None,
    });
    let mag = run(PruneMethod::Magnitude { pattern: Pattern::Unstructured(0.5) });
    let mut wins = 0;
    let mut total = 0;
    for (a, b) in sgpt.reports.iter().zip(&mag.reports) {
        // the magnitude run's Hessians differ slightly after the first
        // pruned block (activations diverge); layer 0 comparisons are exact
        if let (Some(ea), Some(eb)) = (a.sq_error, b.sq_error) {
            total += 1;
            if ea <= eb {
                wins += 1;
            }
        }
    }
    assert!(total >= 12);
    assert!(wins * 10 >= total * 9, "sparsegpt won only {wins}/{total}");
}

#[test]
fn pipeline_quantization_grid_respected() {
    let Some(rt) = runtime() else { return };
    let rt = &rt;
    let (cfg, params, chunks, _ds) = setup(rt);
    let opts = PruneOptions {
        method: PruneMethod::SparseGpt {
            pattern: Pattern::Unstructured(0.5),
            quant_bits: Some(4),
        },
        ..Default::default()
    };
    let out = Pruner::new(rt).prune(params.clone(), &chunks, &opts).unwrap();
    // kept weights take at most 2^4 distinct values per row
    for kind in PRUNABLE_KINDS {
        let w = out.params.get_linear(kind, 0).unwrap();
        for r in 0..w.rows().min(8) {
            let mut vals: Vec<f32> =
                w.row(r).iter().cloned().filter(|&v| v != 0.0).collect();
            vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
            vals.dedup_by(|a, b| (*a - *b).abs() < 1e-7);
            assert!(vals.len() <= 16, "{kind:?} row {r}: {} levels", vals.len());
        }
    }
    let _ = cfg;
}

#[test]
fn pipeline_adaprune_runs_and_prunes() {
    let Some(rt) = runtime() else { return };
    let rt = &rt;
    let (_cfg, params, chunks, _ds) = setup(rt);
    let opts = PruneOptions {
        method: PruneMethod::AdaPrune { sparsity: 0.5 },
        record_errors: true,
        ..Default::default()
    };
    let out = Pruner::new(rt).prune(params.clone(), &chunks, &opts).unwrap();
    assert!((out.overall_sparsity() - 0.5).abs() < 0.01);
    // AdaPrune must also beat plain magnitude on layer error (it
    // reconstructs on the same magnitude mask)
    let mag = Pruner::new(rt)
        .prune(
            params.clone(),
            &chunks,
            &PruneOptions {
                method: PruneMethod::Magnitude { pattern: Pattern::Unstructured(0.5) },
                record_errors: true,
                ..Default::default()
            },
        )
        .unwrap();
    let (a0, m0) = (
        out.reports[0].sq_error.unwrap(),
        mag.reports[0].sq_error.unwrap(),
    );
    assert!(a0 <= m0 * 1.001, "adaprune {a0} vs magnitude {m0}");
}

#[test]
fn pipeline_deterministic_given_seed() {
    let Some(rt) = runtime() else { return };
    let rt = &rt;
    let (_cfg, params, chunks, _ds) = setup(rt);
    let opts = PruneOptions::default();
    let a = Pruner::new(rt).prune(params.clone(), &chunks, &opts).unwrap();
    let b = Pruner::new(rt).prune(params.clone(), &chunks, &opts).unwrap();
    assert_eq!(a.params.data, b.params.data);
}
