//! Golden test for the serve-path telemetry contract: the same
//! hand-verified zero-artifact workload as `serve_events_golden` (3
//! requests, 130-token prompts into nano's 128-token window, batch 2,
//! scripted `cancel=1@3`; 6 steps, 8 tokens, 11 evictions, 2 finished,
//! 1 cancelled) runs with `snap=100` + `clock=mock` + `--metrics-file`,
//! and the one drain-time `metrics-snapshot` event plus the Prometheus
//! dump must match `golden/metrics_snapshot.jsonl` / `golden/metrics.prom`
//! byte for byte after normalization.
//!
//! Normalization keeps exactly the scalars the schedule determines (the
//! `SCHEDULE_PINNED` whitelist) and zeroes everything wall-clock- or
//! host-shaped (histograms, worker stats, peaks that depend on admission
//! interleaving). Snapshot generations are real pins: the engine's drain
//! snapshot is generation 1, and the job's post-run snapshot (report +
//! Prometheus file) is generation 2 — a third snapshot sneaking into the
//! serve path breaks the golden on purpose.
//!
//! Mock-clock discipline: every phase span is bounded by exactly two
//! clock reads with none in between, so under `clock=mock` each recorded
//! duration is exactly one tick (1ms). The report asserts pin that for
//! the solve/pack/prefill spans.

use std::collections::BTreeMap;

use sparsegpt::api::{JobSpec, JsonlSink, ServeReport, ServeSpec, Session};
use sparsegpt::harness::Workspace;
use sparsegpt::runtime::ReferenceBackend;
use sparsegpt::sparse::PackFormat;
use sparsegpt::util::json::Json;

/// Scalars whose values the hand-verified schedule fully determines —
/// the normalizer keeps these verbatim, so the goldens pin them.
const SCHEDULE_PINNED: &[&str] = &[
    "generation",
    "tokens_decoded_total",
    "steps_total",
    "requests_enqueued_total",
    "requests_finished_total",
    "requests_cancelled_total",
    "requests_rejected_total",
    "cache_evictions_total",
    "events_dropped_total",
    "ttft_anchor_missing_total",
    "net_frames_read_total",
    "net_bytes_read_total",
    "net_frames_written_total",
    "net_bytes_written_total",
    "queue_depth",
    "cache_bytes_in_use",
    "connections_open",
];

/// Keep pinned scalars, zero all other numbers, empty histograms/arrays.
fn normalize(v: &Json) -> Json {
    let Json::Obj(m) = v else { return v.clone() };
    let mut out = BTreeMap::new();
    for (k, val) in m {
        let norm = match val {
            Json::Num(_) if SCHEDULE_PINNED.contains(&k.as_str()) => val.clone(),
            Json::Num(_) => Json::Num(0.0),
            // histograms keep their shape, lose their timing-shaped samples
            Json::Obj(_) => Json::parse(r#"{"buckets":[],"count":0,"sum":0}"#).unwrap(),
            Json::Arr(_) => Json::Arr(Vec::new()),
            other => other.clone(),
        };
        out.insert(k.clone(), norm);
    }
    Json::Obj(out)
}

fn run_serve_with_telemetry() -> (String, String, ServeReport) {
    let dir = std::env::temp_dir().join(format!("sgpt_metrics_golden_{}", std::process::id()));
    let ws = Workspace {
        data_dir: dir.join("data"), // absent: the synthetic-calibration fallback engages
        ckpt_dir: dir.join("checkpoints"), // absent: the seed-0 init fallback engages
        report_dir: dir.join("reports"),
        rt: Box::new(ReferenceBackend::new()),
    };
    // the serve_events_golden workload, verbatim — its schedule is already
    // hand-verified there, so the counter values below are known
    let mut spec = ServeSpec::new("nano");
    spec.requests = 3;
    spec.max_new_tokens = 3;
    spec.prompt_len = 130;
    spec.arrival_every = 1;
    spec.max_batch = 2;
    spec.max_wait = 1;
    spec.temperature = 0.0;
    spec.calib = 4;
    spec.cancel = vec![(1, 3)];
    spec.format = PackFormat::QCsr { bits: 4, group: 0 };
    spec.save_store = Some(dir.join("nano-metrics.spkt"));
    // telemetry knobs: 6 steps < 100, so only the drain snapshot fires;
    // the mock clock makes every duration exactly one 1ms tick
    spec.snap_every = 100;
    spec.mock_clock = true;
    let prom_path = dir.join("metrics.prom");
    spec.metrics_file = Some(prom_path.clone());
    let mut sink = JsonlSink::new(Vec::new());
    let mut session = Session::with_workspace(ws);
    let report = session.run(&JobSpec::Serve(spec), &mut sink).unwrap().into_serve().unwrap();
    let prom = std::fs::read_to_string(&prom_path).unwrap();
    std::fs::remove_dir_all(&dir).ok();
    (String::from_utf8(sink.into_inner()).unwrap(), prom, report)
}

#[test]
fn metrics_snapshot_event_and_prometheus_dump_match_goldens() {
    let (jsonl, prom, report) = run_serve_with_telemetry();

    // exactly one metrics-snapshot event (the drain one; 6 steps < snap=100)
    let snaps: Vec<Json> = jsonl
        .lines()
        .map(|l| Json::parse(l).unwrap_or_else(|e| panic!("unparseable line {l:?}: {e:#}")))
        .filter(|v| v.get("reason").unwrap().as_str().unwrap() == "metrics-snapshot")
        .collect();
    assert_eq!(snaps.len(), 1, "only the drain snapshot fires under snap=100");
    let got = normalize(&snaps[0]).to_string_compact() + "\n";
    let want = include_str!("golden/metrics_snapshot.jsonl");
    assert_eq!(
        got, want,
        "metrics-snapshot schema drifted — update \
         rust/tests/golden/metrics_snapshot.jsonl deliberately (the stats \
         frame and Prometheus dump render the same snapshot)"
    );

    // the Prometheus dump: keep the schedule-pinned scalar lines (plus the
    // generation stamp), drop timing-shaped histogram/worker lines
    let mut kept = String::new();
    for line in prom.lines() {
        let metric = match line.strip_prefix("# TYPE sparsegpt_") {
            Some(rest) => rest.split(' ').next().unwrap(),
            None => line
                .strip_prefix("sparsegpt_")
                .unwrap_or("")
                .split([' ', '{'])
                .next()
                .unwrap(),
        };
        if SCHEDULE_PINNED.contains(&metric) || metric == "snapshot_generation" {
            kept.push_str(line);
            kept.push('\n');
        }
    }
    let want_prom = include_str!("golden/metrics.prom");
    assert_eq!(
        kept, want_prom,
        "Prometheus exposition drifted — update rust/tests/golden/metrics.prom \
         deliberately (scrapers parse these lines)"
    );

    // the report embeds the post-run snapshot (generation 2: the drain
    // event consumed 1) and its totals agree with the engine outcome
    let m = &report.metrics;
    let get = |k: &str| m.get(k).unwrap().as_f64().unwrap() as u64;
    assert_eq!(get("generation"), 2);
    assert_eq!(get("tokens_decoded_total") as usize, report.tokens);
    assert_eq!(get("steps_total") as usize, report.steps);
    assert_eq!(get("cache_evictions_total") as usize, report.cache_evictions);
    assert_eq!(get("requests_cancelled_total") as usize, report.cancelled);
    assert_eq!(get("tokens_prefilled_total") as usize, report.prefill_tokens);
    assert_eq!(get("cache_bytes_peak"), report.peak_cache_bytes);
    assert_eq!(get("queue_depth"), 0, "drained");
    assert_eq!(get("cache_bytes_in_use"), 0, "every reservation released");

    // mock-clock discipline: each span is two clock reads with none in
    // between, so every recorded duration is exactly one 1ms tick
    let hist = |k: &str| {
        let h = m.get(k).unwrap();
        let count = h.get("count").unwrap().as_f64().unwrap() as u64;
        let sum = h.get("sum").unwrap().as_f64().unwrap() as u64;
        (count, sum)
    };
    assert_eq!(hist("phase_solve_ns"), (1, 1_000_000), "one prune pass");
    assert_eq!(hist("phase_pack_ns"), (1, 1_000_000), "one pack pass");
    let (prefills, prefill_ns) = hist("phase_prefill_ns");
    assert_eq!(prefills, 3, "every request prefills exactly once");
    assert_eq!(prefill_ns, prefills * 1_000_000);
    let (decodes, decode_ns) = hist("phase_decode_ns");
    assert!(decodes >= 1);
    assert_eq!(decode_ns, decodes * 1_000_000);
    for net in ["phase_net_read_ns", "phase_net_write_ns"] {
        assert_eq!(hist(net), (0, 0), "no sockets in a synthetic run");
    }
}
