//! Failure-injection tests: every malformed input the pipeline can meet in
//! the field must produce a clean error (never corruption or a panic).

use sparsegpt::data::{Dataset, Tokenizer};
use sparsegpt::model::checkpoint::Checkpoint;
use sparsegpt::model::Manifest;
use sparsegpt::solver::hessian::dampened_hinv_chol_f64;
use sparsegpt::tensor::Tensor;
use sparsegpt::util::json::Json;

fn tmpdir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("sgpt_fi_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn manifest_missing_dir_is_clean_error() {
    let err = Manifest::load("/definitely/not/here").unwrap_err();
    assert!(format!("{err:#}").contains("make artifacts"));
}

#[test]
fn manifest_garbage_json_is_clean_error() {
    let d = tmpdir("manifest");
    std::fs::write(d.join("manifest.json"), "{ not json").unwrap();
    assert!(Manifest::load(&d).is_err());
    std::fs::remove_dir_all(&d).ok();
}

#[test]
fn manifest_wrong_schema_is_clean_error() {
    let d = tmpdir("schema");
    std::fs::write(d.join("manifest.json"), r#"{"version": 1}"#).unwrap();
    assert!(Manifest::load(&d).is_err());
    // artifacts present but inputs malformed
    std::fs::write(
        d.join("manifest.json"),
        r#"{"version":1,"seq":128,"vocab":512,"chunk_tokens":1024,"blocksize":128,
            "configs":{},"artifacts":{"x":{"file":"x.hlo.txt","inputs":[["float99",[2]]],"outputs":[]}}}"#,
    )
    .unwrap();
    assert!(Manifest::load(&d).is_err());
    std::fs::remove_dir_all(&d).ok();
}

#[test]
fn checkpoint_truncated_is_clean_error() {
    let d = tmpdir("ckpt");
    let ck = Checkpoint {
        config_name: "nano".into(),
        step: 1,
        params: vec![1.0; 100],
        adam: None,
    };
    let p = d.join("t.ckpt");
    ck.save(&p).unwrap();
    let bytes = std::fs::read(&p).unwrap();
    std::fs::write(&p, &bytes[..bytes.len() - 37]).unwrap();
    assert!(Checkpoint::load(&p).is_err());
    std::fs::remove_dir_all(&d).ok();
}

#[test]
fn checkpoint_wrong_config_rejected() {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if !std::path::Path::new(dir).join("manifest.json").exists() {
        return;
    }
    let m = Manifest::load(dir).unwrap();
    let nano = m.config("nano").unwrap();
    let ck = Checkpoint {
        config_name: "micro".into(),
        step: 0,
        params: vec![0.0; 10],
        adam: None,
    };
    assert!(ck.into_flat_params(nano).is_err());
}

#[test]
fn singular_hessian_fails_or_dampens() {
    // rank-1 Hessian: undampened cholesky must fail; dampened must succeed
    let x = Tensor::new(vec![1, 8], vec![1.0; 8]);
    let h = x.transpose2().matmul(&x);
    assert!(dampened_hinv_chol_f64(&h, 0.0).is_none());
    let u = dampened_hinv_chol_f64(&h, 0.01).unwrap();
    assert!(u.data().iter().all(|v| v.is_finite()));
}

#[test]
fn zero_hessian_guarded() {
    let h = Tensor::zeros(vec![8, 8]);
    // mean diag is 0 -> the guard substitutes 1.0, factor must be finite
    let u = dampened_hinv_chol_f64(&h, 0.01).unwrap();
    assert!(u.data().iter().all(|v| v.is_finite()));
}

#[test]
fn tokenizer_bad_file_is_clean_error() {
    let d = tmpdir("tok");
    let p = d.join("tok.txt");
    std::fs::write(&p, "wrong-header 3\n1 2\n").unwrap();
    assert!(Tokenizer::load(&p).is_err());
    std::fs::write(&p, "sgpt-bpe-v1 5\n1 2\n").unwrap(); // truncated merges
    assert!(Tokenizer::load(&p).is_err());
    std::fs::remove_dir_all(&d).ok();
}

#[test]
fn dataset_odd_byte_length_rejected() {
    let d = tmpdir("ds");
    let p = d.join("x.tokens");
    std::fs::write(&p, [0u8, 1, 2]).unwrap();
    assert!(Dataset::load_tokens("x", &p).is_err());
    std::fs::remove_dir_all(&d).ok();
}

#[test]
fn json_writer_escapes_are_reparseable() {
    let mut obj = std::collections::BTreeMap::new();
    obj.insert("k\"ey\n".to_string(), Json::Str("v\\al\tue \u{7}".into()));
    let s = Json::Obj(obj).to_string_pretty();
    let back = Json::parse(&s).unwrap();
    assert_eq!(back.get("k\"ey\n").unwrap().as_str().unwrap(), "v\\al\tue \u{7}");
}
