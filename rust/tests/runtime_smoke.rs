//! Integration: the Rust runtime executes real AOT artifacts and the HLO
//! solver path agrees with the pure-Rust f64 reference solver.
//!
//! These tests need `make artifacts` to have run; they are skipped (pass
//! trivially) when the artifacts directory is absent so `cargo test` stays
//! green on a fresh checkout.

use sparsegpt::model::Manifest;
use sparsegpt::runtime::{ArgValue, Runtime};
use sparsegpt::solver::hessian::dampened_hinv_chol_f64;
use sparsegpt::solver::sparsegpt_ref::{ref_sparsegpt, Pattern};
use sparsegpt::tensor::Tensor;
use sparsegpt::util::prng::Rng;

fn runtime() -> Option<Runtime> {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if !std::path::Path::new(dir).join("manifest.json").exists() {
        eprintln!("artifacts not built; skipping");
        return None;
    }
    Some(Runtime::with_dir(dir).expect("runtime"))
}

fn random_problem(rng: &mut Rng, r: usize, c: usize) -> (Tensor, Tensor, Tensor) {
    let w = Tensor::new(vec![r, c], (0..r * c).map(|_| rng.normal_f32()).collect());
    let n = 2 * c;
    let x = Tensor::new(vec![n, c], (0..n * c).map(|_| rng.normal_f32()).collect());
    let h = x.transpose2().matmul(&x);
    let hc = dampened_hinv_chol_f64(&h, 0.01).expect("hinv chol");
    (w, h, hc)
}

#[test]
fn hessian_artifact_matches_rust() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(0);
    let n = rt.manifest.chunk_tokens;
    let dim = 64;
    let x: Vec<f32> = (0..n * dim).map(|_| rng.normal_f32()).collect();
    let out = rt.run("hessian_64", &[ArgValue::F32(&x)]).unwrap();
    let xt = Tensor::new(vec![n, dim], x.clone());
    let href = xt.transpose2().matmul(&xt);
    let max_err = out[0]
        .data()
        .iter()
        .zip(href.data())
        .map(|(a, b)| (a - b).abs() / (1.0 + b.abs()))
        .fold(0.0f32, f32::max);
    assert!(max_err < 1e-3, "max_err {max_err}");
}

#[test]
fn hessian_prep_artifact_matches_rust_f64() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(1);
    let dim = 64;
    let n = 2 * dim;
    let x = Tensor::new(vec![n, dim], (0..n * dim).map(|_| rng.normal_f32()).collect());
    let h = x.transpose2().matmul(&x);
    let out = rt
        .run("hessian_prep_64", &[ArgValue::F32(h.data()), ArgValue::Scalar(0.01)])
        .unwrap();
    let href = dampened_hinv_chol_f64(&h, 0.01).unwrap();
    let scale = href.data().iter().fold(0.0f32, |a, &b| a.max(b.abs()));
    let max_err = out[0]
        .data()
        .iter()
        .zip(href.data())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_err / scale < 1e-3, "max_err {max_err} scale {scale}");
}

#[test]
fn sparsegpt_artifact_matches_reference_solver() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(2);
    let (r, c) = (64, 64);
    let (w, _h, hc) = random_problem(&mut rng, r, c);
    let out = rt
        .run(
            "sparsegpt_64x64",
            &[
                ArgValue::F32(w.data()),
                ArgValue::F32(hc.data()),
                ArgValue::Scalar(0.5),
                ArgValue::Scalar(0.0),
            ],
        )
        .unwrap();
    let (w_ref, mask_ref) = ref_sparsegpt(&w, &hc, Pattern::Unstructured(0.5), 0, 128);
    assert_eq!(out[1].data(), mask_ref.data(), "mask mismatch");
    let max_err = out[0]
        .data()
        .iter()
        .zip(w_ref.data())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_err < 5e-4, "weights mismatch {max_err}");
    // density exact
    let kept: f32 = out[1].data().iter().sum();
    assert_eq!(kept as usize, r * c / 2);
}

#[test]
fn sparsegpt24_artifact_enforces_pattern() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(3);
    let (r, c) = (64, 64);
    let (w, _h, hc) = random_problem(&mut rng, r, c);
    let out = rt
        .run(
            "sparsegpt24_64x64",
            &[
                ArgValue::F32(w.data()),
                ArgValue::F32(hc.data()),
                ArgValue::Scalar(0.0),
            ],
        )
        .unwrap();
    let mask = &out[1];
    for row in 0..r {
        for g in (0..c).step_by(4) {
            let kept: f32 = (g..g + 4).map(|j| mask.at2(row, j)).sum();
            assert_eq!(kept, 2.0, "row {row} group {g}");
        }
    }
    // pruned entries are exactly zero in the weights
    for i in 0..r {
        for j in 0..c {
            if mask.at2(i, j) == 0.0 {
                assert_eq!(out[0].at2(i, j), 0.0);
            }
        }
    }
}

#[test]
fn nll_artifact_runs_and_is_finite() {
    let Some(rt) = runtime() else { return };
    let cfg = rt.manifest.config("nano").unwrap().clone();
    let fp = sparsegpt::model::init::init_params(&cfg, 0);
    let mut rng = Rng::new(4);
    let toks: Vec<i32> = (0..cfg.eval_batch * (cfg.seq + 1))
        .map(|_| rng.below(cfg.vocab) as i32)
        .collect();
    let out = rt
        .run("nll_nano", &[ArgValue::F32(&fp.data), ArgValue::I32(&toks)])
        .unwrap();
    assert_eq!(out[0].shape(), &[cfg.eval_batch, cfg.seq]);
    let mean: f32 = out[0].data().iter().sum::<f32>() / out[0].len() as f32;
    assert!(mean.is_finite() && mean > 0.0);
    // roughly log(vocab) at init
    assert!((mean - (cfg.vocab as f32).ln()).abs() < 1.5, "mean {mean}");
}

#[test]
fn runtime_rejects_bad_inputs() {
    let Some(rt) = runtime() else { return };
    let w = vec![0f32; 10];
    assert!(rt.run("sparsegpt_64x64", &[ArgValue::F32(&w)]).is_err());
    assert!(rt.run("does_not_exist", &[]).is_err());
}
