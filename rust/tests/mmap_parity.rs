//! Differential suite for the zero-copy serving tentpole: **a store loaded
//! from mapped pages must be element-identical to the owned-buffer load**
//! — for every packed format (`dense` / `csr` / `csr:perm` / `nm` / the
//! three quantized packings, grouped and ungrouped) — and a model built on
//! mapped sections must decode token streams byte-for-byte equal to one
//! built on copied buffers. On top of the byte-level contract, the fleet
//! leg proves LRU weight residency under a tight `--model-cache-mb` budget
//! returns to zero at drain: every byte a `model-loaded` event reports is
//! matched by a `model-evicted` byte before the engine exits.

use std::path::{Path, PathBuf};

use sparsegpt::model::init::init_params;
use sparsegpt::model::layout::{FlatParams, PRUNABLE_KINDS};
use sparsegpt::model::sparse_store::SparseStore;
use sparsegpt::model::ModelCfg;
use sparsegpt::serve::{
    EngineOptions, ModelFleet, SchedulerPolicy, ServeEngine, ServeEvent, ServeRequest, SparseModel,
};
use sparsegpt::solver::magnitude::{magnitude_prune, magnitude_prune_nm};
use sparsegpt::sparse::{PackFormat, PackPolicy};
use sparsegpt::util::prng::Rng;

fn cfg() -> ModelCfg {
    ModelCfg::from_dims("mmap-parity", 8, 2, 2, 1, 1, 13, 6)
}

/// Prune every prunable linear of a fresh model with `f`.
fn pruned_params(
    cfg: &ModelCfg,
    seed: u64,
    f: impl Fn(&sparsegpt::tensor::Tensor) -> sparsegpt::tensor::Tensor,
) -> FlatParams {
    let mut fp = init_params(cfg, seed);
    for layer in 0..cfg.layers {
        for kind in PRUNABLE_KINDS {
            let w = f(&fp.get_linear(kind, layer).unwrap());
            fp.set_linear(kind, layer, &w).unwrap();
        }
    }
    fp
}

/// Every format the store serializes. N:M formats get 2:4-pruned weights
/// so the structural invariant holds; the rest get unstructured 50%.
fn formats() -> Vec<PackFormat> {
    vec![
        PackFormat::Dense,
        PackFormat::Csr,
        PackFormat::CsrPerm,
        PackFormat::Nm(2, 4),
        PackFormat::QDense { bits: 4, group: 0 },
        PackFormat::QCsr { bits: 4, group: 0 },
        PackFormat::QCsr { bits: 4, group: 2 },
        PackFormat::QNm { bits: 4, group: 0 },
    ]
}

fn params_for(fmt: PackFormat) -> FlatParams {
    let cfg = cfg();
    match fmt {
        PackFormat::Nm(..) | PackFormat::QNm { .. } => {
            pruned_params(&cfg, 4, |w| magnitude_prune_nm(w, 2, 4).0)
        }
        _ => pruned_params(&cfg, 3, |w| magnitude_prune(w, 0.5).0),
    }
}

/// Pack + save one variant, returning its `.spkt` path.
fn save_variant(dir: &Path, fmt: PackFormat) -> PathBuf {
    let fp = params_for(fmt);
    let store = SparseStore::pack(&fp, &PackPolicy::with_format(fmt), "mmap-parity-test").unwrap();
    let path = dir.join(format!("{}.spkt", fmt.label().replace([':', '%'], "_")));
    store.save(&path).unwrap();
    path
}

/// Whether the mapped loader serves this format's streams zero-copy.
/// N:M slot arrays are rebuilt on decode (disk layout != memory layout),
/// so `nm` is the one format that is always owned even from a mapping.
fn maps_zero_copy(fmt: PackFormat) -> bool {
    !matches!(fmt, PackFormat::Nm(..))
}

#[test]
fn mapped_store_is_element_identical_to_owned_load_for_every_format() {
    let dir = std::env::temp_dir().join(format!("sgpt_mmap_parity_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    for fmt in formats() {
        let label = fmt.label();
        let path = save_variant(&dir, fmt);
        let mapped = SparseStore::load(&path).unwrap();
        let owned = SparseStore::load_owned(&path).unwrap();

        assert_eq!(mapped.config_name, owned.config_name, "{label}");
        assert_eq!(mapped.source_label, owned.source_label, "{label}");
        assert_eq!(mapped.n_params, owned.n_params, "{label}");
        assert_eq!(mapped.layers, owned.layers, "{label}");
        assert_eq!(mapped.rest, owned.rest, "{label}: rest stream diverged");
        assert_eq!(mapped.entries.len(), owned.entries.len(), "{label}");
        for (me, oe) in mapped.entries.iter().zip(owned.entries.iter()) {
            assert_eq!(me.layer, oe.layer, "{label}");
            assert_eq!(me.kind, oe.kind, "{label}");
            assert_eq!(
                me.matrix.format_label(),
                oe.matrix.format_label(),
                "{label}: decode picked different formats per backing"
            );
            assert_eq!(
                me.matrix.payload_bytes(),
                oe.matrix.payload_bytes(),
                "{label} {:?}/{}",
                oe.kind,
                oe.layer
            );
            // the core contract: exact element equality, not approximate
            assert_eq!(
                me.matrix.to_dense().data(),
                oe.matrix.to_dense().data(),
                "{label} {:?}/{}: mapped decode diverged from owned",
                oe.kind,
                oe.layer
            );
        }

        // the owned path never claims mapped pages
        assert_eq!(owned.mapped_bytes(), 0, "{label}: owned load must copy");
        assert_eq!(
            mapped.payload_bytes(),
            owned.payload_bytes(),
            "{label}: payload accounting diverged"
        );
        // where the raw-syscall mapping is live, zero-copy formats must
        // actually be served from the mapping, not silently copied
        #[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
        {
            if maps_zero_copy(fmt) {
                assert!(
                    mapped.mapped_bytes() > 0,
                    "{label}: mapped load fell back to copying every stream"
                );
            }
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Random workload: mixed prompt lengths (past the attention window),
/// staggered arrivals, mixed token budgets — the kv-parity shape.
fn workload(rng: &mut Rng, vocab: usize, seq: usize) -> Vec<(usize, ServeRequest)> {
    let n = 1 + rng.below(5);
    (0..n)
        .map(|i| {
            let plen = 1 + rng.below(3 * seq);
            let prompt: Vec<i32> = (0..plen).map(|_| rng.below(vocab) as i32).collect();
            (
                rng.below(4),
                ServeRequest {
                    id: i as u64,
                    prompt,
                    max_new_tokens: 1 + rng.below(2 * seq),
                    seed: rng.next_u64(),
                    model: None,
                },
            )
        })
        .collect()
}

fn token_streams(
    model: &SparseModel,
    opts: EngineOptions,
    reqs: Vec<(usize, ServeRequest)>,
) -> Vec<(u64, Vec<i32>)> {
    let mut out: Vec<(u64, Vec<i32>)> = ServeEngine::new(model, opts)
        .run(reqs, &mut |_| {})
        .unwrap()
        .finished
        .iter()
        .map(|f| (f.id, f.tokens.clone()))
        .collect();
    out.sort_by_key(|(id, _)| *id);
    out
}

#[test]
fn mapped_model_serves_identical_token_streams_to_owned_model() {
    let dir = std::env::temp_dir().join(format!("sgpt_mmap_engine_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = cfg();
    for fmt in formats() {
        let label = fmt.label();
        let path = save_variant(&dir, fmt);
        let m_mapped = SparseModel::from_store(&SparseStore::load(&path).unwrap(), &cfg).unwrap();
        let m_owned =
            SparseModel::from_store(&SparseStore::load_owned(&path).unwrap(), &cfg).unwrap();
        assert_eq!(
            m_mapped.weight_bytes(),
            m_owned.weight_bytes(),
            "{label}: weight accounting depends on the backing"
        );
        assert_eq!(m_owned.mapped_bytes(), 0, "{label}");
        #[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
        {
            if maps_zero_copy(fmt) {
                assert!(m_mapped.mapped_bytes() > 0, "{label}: model dropped its mapping");
            }
        }
        for seed in 0..3u64 {
            let mut rng = Rng::new(seed ^ 0x33AA);
            let reqs = workload(&mut rng, cfg.vocab, cfg.seq);
            let policy = SchedulerPolicy {
                max_batch: 1 + rng.below(4),
                max_wait: rng.below(3),
                queue_cap: 16,
                max_prefill_tokens: [0, cfg.seq][rng.below(2)],
            };
            let opts = EngineOptions {
                policy,
                temperature: [0.0, 0.9][rng.below(2)],
                top_k: 4,
                prefill_chunk: [0, 1, 2, 5][rng.below(4)],
                cache_budget_bytes: [0, m_owned.cache_bytes()][rng.below(2)],
                kv_cache: true,
                ..EngineOptions::default()
            };
            assert_eq!(
                token_streams(&m_mapped, opts, reqs.clone()),
                token_streams(&m_owned, opts, reqs),
                "{label} seed {seed}: mapped weights changed what a request decodes"
            );
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn fleet_eviction_under_tight_budget_returns_residency_to_zero() {
    let dir = std::env::temp_dir().join(format!("sgpt_mmap_fleet_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = cfg();
    // three variants of the same config, one request routed to each plus a
    // default-model request; a one-byte budget forces every new load to
    // evict the previous resident (down to the floor of one)
    let fleet_fmts =
        [PackFormat::Dense, PackFormat::Csr, PackFormat::QDense { bits: 4, group: 0 }];
    let variants: Vec<(String, PathBuf)> = fleet_fmts
        .iter()
        .map(|&fmt| (fmt.label().replace([':', '%'], "_"), save_variant(&dir, fmt)))
        .collect();
    let default_model = SparseModel::from_params(
        &params_for(PackFormat::Dense),
        &PackPolicy::with_format(PackFormat::Dense),
    )
    .unwrap();
    let fleet = ModelFleet::new(&cfg, &variants, 1).unwrap();

    let mut reqs = Vec::new();
    let mut routes = vec![None];
    routes.extend(variants.iter().map(|(name, _)| Some(name.clone())));
    for (i, route) in routes.into_iter().enumerate() {
        reqs.push((
            0,
            ServeRequest {
                id: i as u64,
                prompt: vec![1, 2, 3],
                max_new_tokens: 3,
                seed: 7 + i as u64,
                model: route,
            },
        ));
    }
    let opts = EngineOptions {
        policy: SchedulerPolicy { max_batch: 4, max_wait: 0, queue_cap: 16, max_prefill_tokens: 0 },
        temperature: 0.0,
        top_k: 0,
        ..EngineOptions::default()
    };
    let (mut loaded, mut evicted) = (Vec::new(), Vec::new());
    let out = ServeEngine::new(&default_model, opts)
        .with_fleet(fleet)
        .run(reqs, &mut |e| match e {
            ServeEvent::ModelLoaded { name, bytes, .. } => loaded.push((name.clone(), *bytes)),
            ServeEvent::ModelEvicted { name, bytes, .. } => evicted.push((name.clone(), *bytes)),
            _ => {}
        })
        .unwrap();
    std::fs::remove_dir_all(&dir).ok();

    assert_eq!(out.finished.len(), 4, "default and all three routed requests drain");
    assert_eq!(out.rejected, 0);
    assert_eq!(loaded.len(), 3, "each variant loads exactly once: {loaded:?}");
    assert_eq!(evicted.len(), 3, "every load is matched by an eviction: {evicted:?}");
    let mut l_names: Vec<&str> = loaded.iter().map(|(n, _)| n.as_str()).collect();
    let mut e_names: Vec<&str> = evicted.iter().map(|(n, _)| n.as_str()).collect();
    l_names.sort_unstable();
    e_names.sort_unstable();
    assert_eq!(l_names, e_names, "evictions cover exactly the loaded set");
    let l_bytes: u64 = loaded.iter().map(|(_, b)| b).sum();
    let e_bytes: u64 = evicted.iter().map(|(_, b)| b).sum();
    assert_eq!(l_bytes, e_bytes, "weight residency did not return to zero at drain");
    assert!(l_bytes > 0, "loads must account real weight bytes");
}
